"""FEEL-at-scale: train a language model with the paper's data
selection + IPW aggregation inside the jitted step.

The mesh "data" axis plays the K federated clients: each step draws
Bernoulli(eps) availability, scores every example's last-layer
gradient norm (sigma), solves the exact Problem-4 selection per client
and aggregates with eq.-(19) weights.

Default is a CPU-sized reduced llama config; --full-100m trains a
~100M-parameter llama-family model (use on real hardware for a few
hundred steps).

    PYTHONPATH=src python examples/train_llm_feel.py --steps 30
"""
import argparse
import dataclasses

from repro.configs import get_config, smoke_config
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true",
                    help="~100M-param llama-family config")
    ap.add_argument("--no-feel", action="store_true")
    args = ap.parse_args()

    if args.full_100m:
        cfg = get_config(args.arch).scaled(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=2048, vocab=32000, head_dim=64)
        import jax
        from repro import optim
        from repro.models import (FeelIntegration, init_model,
                                  make_train_step, param_count)
        from repro.launch.shapes import make_optimizer
        params = init_model(jax.random.PRNGKey(0), cfg)
        print(f"100M config: params={param_count(params):,}")
        opt = make_optimizer(cfg)
        opt_state = opt.init(params)
        feel = None if args.no_feel else FeelIntegration(n_clients=4)
        step = jax.jit(make_train_step(cfg, opt, feel=feel),
                       donate_argnums=(0, 1))
        for i in range(args.steps):
            b = train_mod.synth_batch(cfg, jax.random.PRNGKey(100 + i),
                                      args.batch, args.seq, 4,
                                      feel is not None)
            params, opt_state, m = step(params, opt_state, b)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i} loss={float(m['loss']):.4f} "
                      f"sel={float(m['selected_frac']):.3f}", flush=True)
        return

    train_mod.run(args.arch, args.steps, args.batch, args.seq, smoke=True,
                  feel=not args.no_feel)


if __name__ == "__main__":
    main()
