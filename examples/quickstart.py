"""Quickstart: one FEEL communication round, end to end.

Shows the paper's full server-side decision pipeline on a synthetic
round: swap-matching RB assignment (Alg. 2), power allocation (Alg. 3
via the exact closed form), data selection (Alg. 4+5), and the
resulting net cost / convergence-gap objective.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (baseline_scheme, default_system, proposed_scheme,
                        sample_round)

sys_ = default_system(K=10, N=5, Q=2, D_hat=50)
state = sample_round(jax.random.PRNGKey(0), sys_)

print("== proposed scheme (Algorithm 1) ==")
dec = proposed_scheme(sys_, state)
print(f"feasible={dec.feasible} swaps={dec.swaps}")
print(f"net cost           : {dec.net_cost:+.4f}")
print(f"Delta (conv. gap)  : {dec.delta_obj:.1f}")
print(f"samples selected   : {dec.delta.sum(axis=1).astype(int)}")
print(f"RB assignment      : {dec.rho.argmax(axis=1) * dec.rho.max(axis=1)}")

for i in (1, 4):
    bl = baseline_scheme(sys_, state, i, key=jax.random.PRNGKey(1))
    print(f"baseline {i}: net cost {bl.net_cost:+.4f} "
          f"Delta {bl.delta_obj:.1f}")
