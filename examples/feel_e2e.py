"""End-to-end FEEL training (the paper's own experiment, §VI).

Trains the paper's CNN on the synthetic MNIST-like dataset with 10%
mislabeling, K=10 devices (one class each), N=5 RBs, Q=2 — the full
Algorithm-1 loop with wireless costs, availability, selection and
IPW aggregation.  Compare --scheme proposed vs baseline1..baseline4.

    PYTHONPATH=src python examples/feel_e2e.py --rounds 150
"""
import argparse
import json
import types

import jax

from repro import obs
from repro.core import default_system
from repro.data import SyntheticImages, non_iid_split
from repro.fed import FEELConfig, FEELTrainer
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--scheme", default="proposed")
    ap.add_argument("--mislabel", type=float, default=0.1)
    ap.add_argument("--d-hat", type=int, default=60)
    ap.add_argument("--side", type=int, default=20)
    ap.add_argument("--selection", default="faithful",
                    choices=["faithful", "exact"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a repro.obs JSONL telemetry trace "
                         "(per-round stage timings, solver counters, "
                         "per-device energy) and print its summary")
    ap.add_argument("--monitor", action="store_true",
                    help="attach a ConvergenceMonitor checking each round "
                         "against the paper's Lemma-2 bound; print its "
                         "summary (violations go to --trace if given)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="install a process-wide metrics registry and "
                         "write its Prometheus exposition to PATH")
    args = ap.parse_args()

    train = SyntheticImages.make(6000, side=args.side, seed=0)
    test = SyntheticImages.make(1500, side=args.side, seed=1)
    data = non_iid_split(train, test, K=10, per_device=600,
                         mislabel_prop=args.mislabel, seed=0)
    sys_ = default_system(K=10, N=5, Q=2, D_hat=args.d_hat)
    cfg = FEELConfig(scheme=args.scheme, d_hat=args.d_hat,
                     selection_method=args.selection, eval_every=10)
    cc = cnn.CNNConfig(side=args.side)
    params = cnn.init(jax.random.PRNGKey(0), cc)
    model = types.SimpleNamespace(features=cnn.features, apply=cnn.apply,
                                  loss_fn=cnn.loss_fn,
                                  accuracy=cnn.accuracy)
    tele = None
    if args.trace:
        tele = obs.Telemetry(path=args.trace,
                             meta={"source": "examples.feel_e2e",
                                   "scheme": args.scheme,
                                   "rounds": args.rounds})
    reg = None
    if args.metrics:
        reg = obs.Registry()
        obs.metrics.set_default(reg)
    monitor = None
    if args.monitor:
        monitor = obs.ConvergenceMonitor(sys_, telemetry=tele, registry=reg)
    trainer = FEELTrainer(sys_, data, model, params, cfg, telemetry=tele,
                          monitor=monitor)
    metrics = trainer.run(args.rounds, verbose=True)
    final = [m for m in metrics if m.test_acc is not None][-1]
    print(f"\nFINAL: acc={final.test_acc:.3f} "
          f"cum_net_cost={final.cum_net_cost:+.3f}")
    if tele is not None:
        tele.close()
        print(f"\ntelemetry trace -> {args.trace}")
        print("name,us_per_call,derived")
        obs.emit_summary(obs.summarize(tele.events))
    if monitor is not None:
        s = monitor.summary()
        print(f"\nmonitor: rounds={s['rounds']} "
              f"bound_gap_ratio={s['bound_gap_ratio']:.3f} "
              f"violations={s['violations'] or '{}'}")
    if reg is not None:
        obs.metrics.set_default(None)
        with open(args.metrics, "w") as f:
            f.write(reg.render())
        print(f"metrics exposition -> {args.metrics}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([m.__dict__ for m in metrics], f)


if __name__ == "__main__":
    main()
