"""End-to-end FEEL training (the paper's own experiment, §VI).

Trains the paper's CNN on the synthetic MNIST-like dataset with 10%
mislabeling, K=10 devices (one class each), N=5 RBs, Q=2 — the full
Algorithm-1 loop with wireless costs, availability, selection and
IPW aggregation.  Compare --scheme proposed vs baseline1..baseline4.

    PYTHONPATH=src python examples/feel_e2e.py --rounds 150
"""
import argparse
import json
import types

import jax

from repro import obs
from repro.core import default_system
from repro.data import SyntheticImages, non_iid_split
from repro.fed import FEELConfig, FEELTrainer
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--scheme", default="proposed")
    ap.add_argument("--mislabel", type=float, default=0.1)
    ap.add_argument("--d-hat", type=int, default=60)
    ap.add_argument("--side", type=int, default=20)
    ap.add_argument("--selection", default="faithful",
                    choices=["faithful", "exact"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a repro.obs JSONL telemetry trace "
                         "(per-round stage timings, solver counters, "
                         "per-device energy) and print its summary")
    args = ap.parse_args()

    train = SyntheticImages.make(6000, side=args.side, seed=0)
    test = SyntheticImages.make(1500, side=args.side, seed=1)
    data = non_iid_split(train, test, K=10, per_device=600,
                         mislabel_prop=args.mislabel, seed=0)
    sys_ = default_system(K=10, N=5, Q=2, D_hat=args.d_hat)
    cfg = FEELConfig(scheme=args.scheme, d_hat=args.d_hat,
                     selection_method=args.selection, eval_every=10)
    cc = cnn.CNNConfig(side=args.side)
    params = cnn.init(jax.random.PRNGKey(0), cc)
    model = types.SimpleNamespace(features=cnn.features, apply=cnn.apply,
                                  loss_fn=cnn.loss_fn,
                                  accuracy=cnn.accuracy)
    tele = None
    if args.trace:
        tele = obs.Telemetry(path=args.trace,
                             meta={"source": "examples.feel_e2e",
                                   "scheme": args.scheme,
                                   "rounds": args.rounds})
    trainer = FEELTrainer(sys_, data, model, params, cfg, telemetry=tele)
    metrics = trainer.run(args.rounds, verbose=True)
    final = [m for m in metrics if m.test_acc is not None][-1]
    print(f"\nFINAL: acc={final.test_acc:.3f} "
          f"cum_net_cost={final.cum_net_cost:+.3f}")
    if tele is not None:
        tele.close()
        print(f"\ntelemetry trace -> {args.trace}")
        print("name,us_per_call,derived")
        obs.emit_summary(obs.summarize(tele.events))
    if args.out:
        with open(args.out, "w") as f:
            json.dump([m.__dict__ for m in metrics], f)


if __name__ == "__main__":
    main()
