"""End-to-end FEEL training (the paper's own experiment, §VI).

Trains the paper's CNN on the synthetic MNIST-like dataset with 10%
mislabeling, K=10 devices (one class each), N=5 RBs, Q=2 — the full
Algorithm-1 loop with wireless costs, availability, selection and
IPW aggregation.  Compare --scheme proposed vs baseline1..baseline4.

    PYTHONPATH=src python examples/feel_e2e.py --rounds 150
"""
import argparse
import json
import sys
import types

import jax
import numpy as np

from repro import obs
from repro.core import default_system
from repro.data import SyntheticImages, non_iid_split
from repro.fed import (CHAOS_SPEC, FEELConfig, FEELTrainer, FaultSpec,
                       ResilienceConfig)
from repro.models import cnn


def parse_faults(arg):
    """--faults chaos | --faults '{"seed": 1, "dropout_prob": 0.2}'."""
    if arg is None:
        return None
    if arg == "chaos":
        return CHAOS_SPEC
    return FaultSpec.from_dict(json.loads(arg))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--scheme", default="proposed")
    ap.add_argument("--mislabel", type=float, default=0.1)
    ap.add_argument("--d-hat", type=int, default=60)
    ap.add_argument("--side", type=int, default=20)
    ap.add_argument("--selection", default="faithful",
                    choices=["faithful", "exact"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a repro.obs JSONL telemetry trace "
                         "(per-round stage timings, solver counters, "
                         "per-device energy) and print its summary")
    ap.add_argument("--dash", default=None, metavar="PATH",
                    help="with --trace: also render the trace as a "
                         "self-contained HTML round dashboard at PATH "
                         "(same as `python -m repro.obs dash`)")
    ap.add_argument("--monitor", action="store_true",
                    help="attach a ConvergenceMonitor checking each round "
                         "against the paper's Lemma-2 bound; print its "
                         "summary (violations go to --trace if given)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="install a process-wide metrics registry and "
                         "write its Prometheus exposition to PATH")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="inject faults: 'chaos' for the aggressive "
                         "preset, or a FaultSpec JSON object "
                         "(docs/robustness.md)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="directory for periodic trainer checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    metavar="N", help="checkpoint every N rounds")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the checkpoint in --checkpoint-dir "
                         "before running")
    ap.add_argument("--check-resume", action="store_true",
                    help="self-test: run to completion, then replay the "
                         "second half from a mid-run checkpoint with a "
                         "fresh trainer and assert bit-identical params "
                         "(exits non-zero on mismatch)")
    args = ap.parse_args()
    faults = parse_faults(args.faults)

    train = SyntheticImages.make(6000, side=args.side, seed=0)
    test = SyntheticImages.make(1500, side=args.side, seed=1)
    data = non_iid_split(train, test, K=10, per_device=600,
                         mislabel_prop=args.mislabel, seed=0)
    sys_ = default_system(K=10, N=5, Q=2, D_hat=args.d_hat)
    cfg = FEELConfig(scheme=args.scheme, d_hat=args.d_hat,
                     selection_method=args.selection, eval_every=10)
    cc = cnn.CNNConfig(side=args.side)
    params = cnn.init(jax.random.PRNGKey(0), cc)
    model = types.SimpleNamespace(features=cnn.features, apply=cnn.apply,
                                  loss_fn=cnn.loss_fn,
                                  accuracy=cnn.accuracy)
    tele = None
    if args.trace:
        tele = obs.Telemetry(path=args.trace,
                             meta={"source": "examples.feel_e2e",
                                   "scheme": args.scheme,
                                   "rounds": args.rounds})
    reg = None
    if args.metrics:
        reg = obs.Registry()
        obs.metrics.set_default(reg)
    monitor = None
    if args.monitor:
        monitor = obs.ConvergenceMonitor(sys_, telemetry=tele, registry=reg)

    resilience = None
    if (faults is not None or args.checkpoint_every or args.checkpoint_dir
            or args.check_resume):
        resilience = ResilienceConfig(checkpoint_every=args.checkpoint_every,
                                      checkpoint_dir=args.checkpoint_dir)

    def make_trainer(res=resilience, quiet=False):
        p0 = cnn.init(jax.random.PRNGKey(0), cc)
        return FEELTrainer(sys_, data, model, p0, cfg,
                           telemetry=None if quiet else tele,
                           monitor=None if quiet else monitor,
                           faults=faults, resilience=res)

    trainer = make_trainer()
    if args.resume:
        start = trainer.resume()
        print(f"resumed from round {start}")
    metrics = trainer.run(args.rounds, verbose=True)
    final = [m for m in metrics if m.test_acc is not None][-1]

    if args.check_resume:
        import tempfile
        half = max(args.rounds // 2, 1)
        with tempfile.TemporaryDirectory() as tmp:
            # threshold 1: any surviving NaN upload quarantines, so the
            # chaos run deterministically exercises the quarantine path
            res = ResilienceConfig(checkpoint_every=half,
                                   checkpoint_dir=tmp,
                                   quarantine_threshold=1)
            full = make_trainer(res=res, quiet=True)
            ms_full = full.run(args.rounds)
            partial = make_trainer(res=res, quiet=True)
            partial.run(half)  # writes the checkpoint at round `half`
            resumed = make_trainer(res=res, quiet=True)
            start = resumed.resume()
            resumed.run(args.rounds)
        same = all(np.array_equal(a, b)
                   for a, b in zip(jax.tree.leaves(full.params),
                                   jax.tree.leaves(resumed.params)))
        ok_finite = all(bool(np.isfinite(np.asarray(x)).all())
                        for x in jax.tree.leaves(full.params))
        n_quar = sum(m.n_quarantined for m in ms_full)
        print(f"\ncheck-resume: resumed_at={start} bit_identical={same} "
              f"finite={ok_finite} quarantined_device_rounds={n_quar}")
        if not (same and ok_finite):
            print("check-resume FAILED", file=sys.stderr)
            raise SystemExit(1)
        if faults is not None and faults.nan_prob > 0 and n_quar == 0:
            print("check-resume FAILED: chaos plan injected NaN uploads "
                  "but quarantine never triggered", file=sys.stderr)
            raise SystemExit(1)
    print(f"\nFINAL: acc={final.test_acc:.3f} "
          f"cum_net_cost={final.cum_net_cost:+.3f}")
    if tele is not None:
        tele.close()
        print(f"\ntelemetry trace -> {args.trace}")
        print("name,us_per_call,derived")
        obs.emit_summary(obs.summarize(tele.events))
        if args.dash:
            obs.write_dashboard(args.trace, args.dash)
            print(f"round dashboard -> {args.dash}")
        print(f"inspect: python -m repro.obs export {args.trace}  "
              f"(Perfetto), ... diff, ... dash")
    if monitor is not None:
        s = monitor.summary()
        print(f"\nmonitor: rounds={s['rounds']} "
              f"bound_gap_ratio={s['bound_gap_ratio']:.3f} "
              f"violations={s['violations'] or '{}'}")
    if reg is not None:
        obs.metrics.set_default(None)
        with open(args.metrics, "w") as f:
            f.write(reg.render())
        print(f"metrics exposition -> {args.metrics}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([m.__dict__ for m in metrics], f)


if __name__ == "__main__":
    main()
