"""Batched serving example: prefill + KV-cache greedy decode.

Runs the same serve_step the decode dry-run shapes lower, on a
CPU-sized reduced config.  Try --arch deepseek-v3-671b --mla-absorbed
to exercise the absorbed-MLA decode path, or --arch falcon-mamba-7b
for the O(1)-state SSM decode.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-12b
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--mla-absorbed", action="store_true")
    args = ap.parse_args()
    serve(args.arch, args.batch, args.prompt_len, args.new_tokens,
          smoke=True, mla_absorbed=args.mla_absorbed)


if __name__ == "__main__":
    main()
