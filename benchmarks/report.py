"""Regenerate the EXPERIMENTS.md roofline tables + variant comparison
from experiments/dryrun.jsonl.

    PYTHONPATH=src python -m benchmarks.report            # markdown
    PYTHONPATH=src python -m benchmarks.report --variants # §Perf deltas
"""
from __future__ import annotations

import argparse

from .roofline import load


def baseline_tables():
    recs = load()
    out = []
    for mesh in ("16x16", "2x16x16"):
        out.append(f"\n### Mesh {mesh} (baseline)\n")
        out.append("| arch | shape | compute s | memory s (UB) | "
                   "collective s | bottleneck | MODEL/HLO | "
                   "params/dev GB |")
        out.append("|---|---|---|---|---|---|---|---|")
        for (a, s, m, v), r in recs.items():
            if m != mesh or v != "baseline":
                continue
            if not r.get("ok"):
                out.append(f"| {a} | {s} | - | - | - | FAILED | - | - |")
                continue
            args = (r["memory"].get("argument_bytes") or 0) / 1e9
            out.append(
                f"| {a} | {s} | {r['compute_term_s']:.3g} | "
                f"{r['memory_term_s']:.3g} | "
                f"{r['collective_term_s']:.3g} | {r['bottleneck']} | "
                f"{(r.get('useful_ratio') or 0):.3f} | {args:.2f} |")
    return "\n".join(out)


def variant_table():
    recs = load()
    rows = {}
    for (a, s, m, v), r in recs.items():
        if m != "16x16" or not r.get("ok"):
            continue
        rows.setdefault((a, s), {})[v] = r
    out = ["| arch | shape | variant | compute s | memory s | "
           "collective s | useful |", "|---|---|---|---|---|---|---|"]
    for (a, s), vs in rows.items():
        if len(vs) < 2:
            continue
        for v, r in vs.items():
            out.append(f"| {a} | {s} | {v} | {r['compute_term_s']:.3g} | "
                       f"{r['memory_term_s']:.3g} | "
                       f"{r['collective_term_s']:.3g} | "
                       f"{(r.get('useful_ratio') or 0):.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variants", action="store_true")
    args = ap.parse_args()
    print(variant_table() if args.variants else baseline_tables())


if __name__ == "__main__":
    main()
