"""Paper Fig. 6: effect of device availability eps on accuracy and
cumulative net cost (eps_k = eps for all k)."""
from __future__ import annotations

import os

from .common import emit, run_scheme, save_json


def run(rounds: int | None = None, eps_values=(0.2, 0.5, 1.0)):
    rounds = rounds or int(os.environ.get("REPRO_FIG6_ROUNDS", "40"))
    results = {}
    for eps in eps_values:
        for scheme in ("proposed", "baseline4"):
            r = run_scheme(scheme, rounds, eps_override=eps)
            results[f"{scheme}@{eps}"] = r
            emit(f"fig6_{scheme}_eps{eps}", r["us_per_round"],
                 f"acc={r['final_acc']:.3f};"
                 f"cum_cost={r['cum_net_cost']:+.3f}")
    save_json("fig6_availability.json", results)
    return results


if __name__ == "__main__":
    run()
