"""Roofline table from the dry-run records (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun.jsonl, keeps the latest record per
(arch, shape, mesh, variant), prints the three roofline terms, the
bottleneck, and MODEL_FLOPS/HLO_FLOPs usefulness ratio.

``--trace PATH`` switches to the measured FEEL roofline: reads a
repro.obs JSONL trace recorded with ``Telemetry(profile=True)`` and
prints one ``roofline_feel_<stage>`` row per profiled kernel — HLO
FLOPs/bytes per call, arithmetic intensity, achieved GFLOP/s and
achieved/peak utilization (schema v2 ``profile`` events joined against
that stage's mean wall-clock).
"""
from __future__ import annotations

import json
import os
from collections import OrderedDict

from .common import emit

DRYRUN = os.environ.get("REPRO_DRYRUN", "experiments/dryrun.jsonl")


def load(path: str = DRYRUN):
    recs = OrderedDict()
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r["arch"], r["shape"], r["mesh"],
                   r.get("variant", "baseline"))
            recs[key] = r  # later lines win
    return recs


def table(recs, mesh_filter: str | None = "16x16",
          variant: str = "baseline"):
    rows = []
    for (arch, shape, mesh, var), r in recs.items():
        if mesh_filter and mesh != mesh_filter:
            continue
        if var != variant:
            continue
        if not r.get("ok"):
            rows.append((arch, shape, mesh, "FAILED", r.get("error")))
            continue
        rows.append((arch, shape, mesh, r))
    return rows


def run():
    recs = load()
    if not recs:
        emit("roofline", 0.0, "no dryrun.jsonl yet")
        return
    for mesh in ("16x16", "2x16x16"):
        for row in table(recs, mesh):
            arch, shape = row[0], row[1]
            r = row[3]
            if r == "FAILED":
                emit(f"roofline_{mesh}_{arch}_{shape}", 0.0, "FAILED")
                continue
            step = max(r["compute_term_s"], r["memory_term_s"],
                       r["collective_term_s"])
            emit(f"roofline_{mesh}_{arch}_{shape}", step * 1e6,
                 f"bottleneck={r['bottleneck']};"
                 f"compute={r['compute_term_s']:.3g}s;"
                 f"memory={r['memory_term_s']:.3g}s;"
                 f"collective={r['collective_term_s']:.3g}s;"
                 f"useful={r.get('useful_ratio') or 0:.2f}")


def run_trace(path: str) -> None:
    """Measured FEEL roofline rows from a profile-enabled trace."""
    from repro import obs

    s = obs.summarize(obs.load_trace(path))
    rl = s.roofline()
    if not rl:
        emit("roofline_feel", 0.0,
             "no profile events (record with Telemetry(profile=True))")
        return
    for stage, r in sorted(rl.items()):
        ai = (r["flops"] / r["bytes_accessed"]
              if r["bytes_accessed"] > 0 else 0.0)
        emit(f"roofline_feel_{stage}", r["per_call_s"] * 1e6,
             f"kernel={r['kernel']};flops={r['flops']:.3e};"
             f"bytes={r['bytes_accessed']:.3e};intensity={ai:.2f};"
             f"achieved_gflops={r['achieved_flops_per_s'] / 1e9:.2f};"
             f"util={r['utilization']:.4f}")


def markdown_table(mesh: str = "16x16", variant: str = "baseline") -> str:
    """Render §Roofline markdown (used to build EXPERIMENTS.md)."""
    recs = load()
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | MODEL/HLO | what would move it |",
             "|---|---|---|---|---|---|---|---|"]
    for row in table(recs, mesh, variant):
        arch, shape = row[0], row[1]
        r = row[3]
        if r == "FAILED":
            lines.append(f"| {arch} | {shape} | - | - | - | FAILED | - | "
                         f"{row[4]} |")
            continue
        lines.append(
            f"| {arch} | {shape} | {r['compute_term_s']:.3g} | "
            f"{r['memory_term_s']:.3g} | {r['collective_term_s']:.3g} | "
            f"{r['bottleneck']} | {r.get('useful_ratio') or 0:.2f} | |")
    return "\n".join(lines)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="repro.obs JSONL trace (profile=True) to render "
                         "instead of the dryrun records")
    a = ap.parse_args()
    print("name,us_per_call,derived")
    if a.trace:
        run_trace(a.trace)
    else:
        run()
