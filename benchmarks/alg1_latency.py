"""Latency of the server-side Algorithm-1 components (the paper's
complexity analysis, §IV/§V): swap matching, power allocation (closed
form + CCP), data selection (gradient projection + recovery, and the
exact oracle)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import default_system, matching, power, sample_round, selection

from .common import emit


def _time(fn, n=3):
    fn()  # warmup / jit
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6


def run():
    sys_ = default_system(K=10, N=5, Q=2, D_hat=200)
    st = sample_round(jax.random.PRNGKey(0), sys_)

    us = _time(lambda: matching.swap_matching(sys_, st.h, st.alpha), n=2)
    emit("alg2_swap_matching", us, "evaluator=closed_form")

    res = matching.swap_matching(sys_, st.h, st.alpha)
    rho = jnp.asarray(res.rho)
    us = _time(lambda: jax.block_until_ready(
        power.closed_form_power(sys_, rho, st.h, st.alpha)[0]))
    emit("power_closed_form", us, "beyond_paper_exact")

    t0 = time.time()
    power.ccp_power(sys_, rho, st.h, st.alpha)
    emit("alg3_ccp_power", (time.time() - t0) * 1e6, "paper_faithful")

    us = _time(lambda: jax.block_until_ready(
        selection.faithful_selection(sys_, st.sigma, st.sigma_mask,
                                     steps=400)), n=2)
    emit("alg4_5_selection_faithful", us, "gp400+lambda_recovery")

    us = _time(lambda: jax.block_until_ready(
        selection.exact_selection(sys_, st.sigma, st.sigma_mask)))
    emit("selection_exact_oracle", us, "beyond_paper_exact")


if __name__ == "__main__":
    run()
