"""Fault-tolerance overhead: per-round cost of the resilience layer.

Three configurations over the same reduced paper-§VI setup:

* ``plain``      — pre-fault-tolerance trainer (faults=None, no
  resilience): the bit-identity baseline;
* ``resilient``  — resilience on, a fault plan whose rates are all 0:
  measures the pure bookkeeping overhead of the layer;
* ``chaos``      — the aggressive ``CHAOS_SPEC`` preset (30% dropout,
  stragglers, NaN uploads, forced solver failures): measures a round
  under fire, including fallback solves and quarantine screening.

Also emits the checkpoint write/restore latency.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.fed import CHAOS_SPEC, FaultSpec, ResilienceConfig

from .common import emit, make_feel_trainer

ROUNDS = 6


def _run(name: str, derived: str, **kw) -> None:
    tr = make_feel_trainer("proposed", side=12, d_hat=24, gp_steps=60,
                           **kw)
    tr.run_round(0)  # warmup / jit compile outside the timed window
    t0 = time.time()
    ms = [tr.run_round(i) for i in range(1, 1 + ROUNDS)]
    us = (time.time() - t0) / ROUNDS * 1e6
    dropped = sum(m.n_dropped for m in ms)
    fb = sum(len(m.fallbacks) for m in ms)
    emit(name, us, f"{derived};dropped={dropped};fallbacks={fb}")


def run():
    _run("chaos_round_plain", "faults=off;resilience=off")
    _run("chaos_round_resilient", "faults=0-rate;resilience=on",
         faults=FaultSpec(seed=0), resilience=ResilienceConfig())
    _run("chaos_round_chaos", "faults=CHAOS_SPEC;resilience=on",
         faults=CHAOS_SPEC, resilience=ResilienceConfig())

    # checkpoint write / restore latency
    tr = make_feel_trainer("proposed", side=12, d_hat=24, gp_steps=60,
                           resilience=ResilienceConfig())
    tr.run_round(0)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "bench_ckpt")
        t0 = time.time()
        for _ in range(5):
            tr.save_checkpoint(path=path, next_round=1)
        emit("chaos_checkpoint_save", (time.time() - t0) / 5 * 1e6,
             "atomic npz+meta")
        t0 = time.time()
        for _ in range(5):
            tr.resume(path=path)
        emit("chaos_checkpoint_resume", (time.time() - t0) / 5 * 1e6,
             "restore params+opt+rng")
        n_bytes = os.path.getsize(path + ".npz")
    emit("chaos_checkpoint_bytes", 0.0, f"npz_bytes={n_bytes}")


if __name__ == "__main__":
    run()
