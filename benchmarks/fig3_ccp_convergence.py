"""Paper Fig. 3: Algorithm 3 (CCP power allocation) convergence from
different random feasible initial points — all trajectories must reach
the same objective, in a handful of iterations."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import default_system, matching, power, sample_round

from .common import emit, save_json


def run(n_inits: int = 5, seed: int = 7):
    sys_ = default_system(K=10, N=5, Q=2, D_hat=20)
    st = sample_round(jax.random.PRNGKey(seed), sys_)
    res = matching.swap_matching(sys_, st.h, st.alpha)
    rho = jnp.asarray(res.rho)
    p_cf, _ = power.closed_form_power(sys_, rho, st.h, st.alpha)
    cost_cf = float(jnp.sum(sys_.c[:, None] * rho * p_cf) * sys_.T)

    rng = np.random.default_rng(seed)
    trajs = []
    t0 = time.time()
    for i in range(n_inits):
        scale = float(rng.uniform(1.2, 4.0))
        p0 = jnp.minimum(p_cf * scale,
                         sys_.p_max[:, None] * rho * (1 - 1e-4))
        out = power.ccp_power(sys_, rho, st.h, st.alpha, p0=p0)
        trajs.append([float(x) for x in out.trajectory])
    dt = time.time() - t0

    finals = [t[-1] for t in trajs]
    spread = (max(finals) - min(finals)) / max(max(finals), 1e-12)
    iters = [len(t) - 1 for t in trajs]
    save_json("fig3_ccp.json", {"trajectories": trajs,
                                "closed_form": cost_cf,
                                "final_spread_rel": spread,
                                "iterations": iters})
    emit("fig3_ccp_convergence", dt / n_inits * 1e6,
         f"spread={spread:.2e};iters={max(iters)};"
         f"vs_closed_form={abs(finals[0] - cost_cf) / cost_cf:.2e}")
    return spread, iters


if __name__ == "__main__":
    run()
