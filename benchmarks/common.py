"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import time
import types

import jax
import numpy as np

from repro.core import default_system
from repro.data import SyntheticImages, non_iid_split
from repro.fed import FEELConfig, FEELTrainer
from repro.models import cnn

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "experiments")


def save_json(name: str, obj) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(obj, f, indent=1)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def make_feel_trainer(scheme: str, *, rounds_seed: int = 0, K: int = 10,
                      side: int = 16, d_hat: int = 40,
                      mislabel_prop: float = 0.1, eps_override=None,
                      selection: str = "faithful", gp_steps: int = 150,
                      faults=None, resilience=None):
    """Paper §VI setup, reduced for the CPU container: smaller images /
    D̂ but identical structure (non-IID one-class devices, N=5 RBs,
    Q=2, odd/even cost-reward-availability asymmetry)."""
    train = SyntheticImages.make(4000, side=side, seed=0)
    test = SyntheticImages.make(1000, side=side, seed=1)
    data = non_iid_split(train, test, K=K, per_device=400,
                         mislabel_prop=mislabel_prop, seed=rounds_seed)
    sys_ = default_system(K=K, N=5, Q=2, D_hat=d_hat)
    if eps_override is not None:
        import dataclasses
        import jax.numpy as jnp
        sys_ = dataclasses.replace(
            sys_, eps=jnp.full((K,), float(eps_override)))
    cfg = FEELConfig(scheme=scheme, d_hat=d_hat, eval_every=10,
                     selection_method=selection, gp_steps=gp_steps,
                     seed=rounds_seed)
    cc = cnn.CNNConfig(side=side)
    params = cnn.init(jax.random.PRNGKey(rounds_seed), cc)
    model = types.SimpleNamespace(features=cnn.features, apply=cnn.apply,
                                  loss_fn=cnn.loss_fn,
                                  accuracy=cnn.accuracy)
    return FEELTrainer(sys_, data, model, params, cfg,
                       faults=faults, resilience=resilience)


def run_scheme(scheme: str, rounds: int, **kw):
    tr = make_feel_trainer(scheme, **kw)
    t0 = time.time()
    ms = tr.run(rounds)
    dt = time.time() - t0
    accs = [(m.round, m.test_acc) for m in ms if m.test_acc is not None]
    return {
        "scheme": scheme,
        "rounds": rounds,
        "acc_curve": accs,
        "final_acc": accs[-1][1],
        "cum_net_cost": ms[-1].cum_net_cost,
        "cost_curve": [(m.round, m.cum_net_cost) for m in ms],
        "bad_frac_last": float(np.mean(
            [m.frac_mislabeled_selected for m in ms[-10:]])),
        "seconds": dt,
        "us_per_round": dt / max(rounds, 1) * 1e6,
    }
