"""Paper Fig. 4: test accuracy + cumulative net cost of the proposed
scheme vs baselines 1-4 over communication rounds (10% mislabeling).

Reduced defaults for the CPU container (smaller images/D̂/rounds); the
structure — non-IID single-class devices, odd/even asymmetric costs,
availability, NOMA RBs — matches §VI-A exactly.
"""
from __future__ import annotations

import os

from .common import emit, run_scheme, save_json

SCHEMES = ["proposed", "baseline1", "baseline2", "baseline3", "baseline4"]


def run(rounds: int | None = None):
    rounds = rounds or int(os.environ.get("REPRO_FIG4_ROUNDS", "60"))
    results = {}
    for scheme in SCHEMES:
        results[scheme] = run_scheme(scheme, rounds)
        emit(f"fig4_{scheme}", results[scheme]["us_per_round"],
             f"acc={results[scheme]['final_acc']:.3f};"
             f"cum_cost={results[scheme]['cum_net_cost']:+.3f};"
             f"bad_sel={results[scheme]['bad_frac_last']:.3f}")
    best_bl = max(results[s]["final_acc"] for s in SCHEMES[1:])
    gain = results["proposed"]["final_acc"] - best_bl
    emit("fig4_summary", 0.0,
         f"acc_gain_vs_best_baseline={gain:+.3f}")
    save_json("fig4_convergence_cost.json", results)
    return results


if __name__ == "__main__":
    run()
