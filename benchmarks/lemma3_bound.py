"""Executable Lemma 3: track the multi-round convergence upper bound
against the observed optimality gap on a strongly-convex quadratic
(the setting where the paper's assumptions hold exactly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import convergence, default_system
from repro.core import delta as delta_mod
from repro.fed.server import aggregate_gradients

from .common import emit, save_json


def run(rounds: int = 30, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    K, J, P = 6, 8, 12
    sys_ = default_system(K=K, N=3, Q=2, D_hat=J)
    A = jax.random.normal(key, (K, J, P)) * 0.5
    w_star = jnp.mean(A.reshape(-1, P), axis=0)
    mu = beta = 1.0  # quadratic: exactly 1-strongly-convex, 1-smooth
    eta = 0.15

    def L(w):
        return 0.5 * float(jnp.mean(jnp.sum((w[None, None] - A) ** 2, -1)))

    w = jnp.ones(P) * 3.0
    gap0 = L(w) - L(w_star)
    etas, deltas, gaps = [], [], [gap0]
    for i in range(rounds):
        g = w[None, None, :] - A
        sigma = jnp.sum(g * g, axis=-1)
        dlt = jnp.ones((K, J))
        deltas.append(float(delta_mod.delta(sys_, dlt, sigma)))
        etas.append(eta)
        a = (jax.random.uniform(jax.random.fold_in(key, i), (K,))
             < sys_.eps).astype(jnp.float32)
        ghat = aggregate_gradients(sys_, jnp.mean(g, axis=1), a)
        w = w - eta * ghat
        gaps.append(L(w) - L(w_star))

    bounds = [convergence.multi_round_bound(sys_, gap0, mu, beta,
                                            etas[:i + 1], deltas[:i + 1])
              for i in range(rounds)]
    # observed gap must stay under the bound (in expectation; single
    # trajectory can wiggle — check the running mean trend)
    violations = sum(g > b * 1.5 for g, b in zip(gaps[1:], bounds))
    save_json("lemma3_bound.json",
              {"gaps": gaps, "bounds": bounds, "violations": violations})
    emit("lemma3_bound", 0.0,
         f"final_gap={gaps[-1]:.3e};final_bound={bounds[-1]:.3e};"
         f"violations={violations}/{rounds}")
    return gaps, bounds


if __name__ == "__main__":
    run()
