"""Benchmark harness: one entry per paper table/figure + the roofline
report.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --fast     # skip fig4/5/6
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the multi-round training figures")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: alg1,fig3,lemma3,fig4,"
                         "fig5,fig6,roofline")
    args = ap.parse_args()

    from . import (alg1_latency, fig3_ccp_convergence, fig4_convergence_cost,
                   fig5_mislabel, fig6_availability, lemma3_bound, roofline)

    benches = [
        ("alg1", alg1_latency.run),
        ("fig3", fig3_ccp_convergence.run),
        ("lemma3", lemma3_bound.run),
        ("roofline", roofline.run),
    ]
    if not args.fast:
        benches += [
            ("fig4", fig4_convergence_cost.run),
            ("fig5", fig5_mislabel.run),
            ("fig6", fig6_availability.run),
        ]
    if args.only:
        keep = set(args.only.split(","))
        benches = [b for b in benches if b[0] in keep]

    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches:
        try:
            fn()
        except Exception as e:  # keep the harness going
            failed.append(name)
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
