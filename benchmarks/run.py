"""Benchmark harness: one entry per paper table/figure + the roofline
report.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --fast     # skip fig4/5/6
    PYTHONPATH=src python -m benchmarks.run --trace t.jsonl
                          # + record a repro.obs telemetry trace and
                          #   append its telemetry.* rows to the CSV
    PYTHONPATH=src python -m benchmarks.run --metrics m.prom
                          # + install a process-wide metrics registry and
                          #   write its Prometheus exposition at the end
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the multi-round training figures")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: alg1,fig3,lemma3,fig4,"
                         "fig5,fig6,roofline,chaos")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a repro.obs JSONL telemetry trace and "
                         "append its summary rows to the CSV output")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="install a process-wide metrics registry and "
                         "write its Prometheus exposition to PATH")
    args = ap.parse_args()

    tele = None
    reg = None
    if args.trace:
        from repro import obs

        tele = obs.Telemetry(path=args.trace,
                             meta={"source": "benchmarks.run",
                                   "argv": sys.argv[1:]})
        obs.set_default(tele)
    if args.metrics:
        from repro import obs

        reg = obs.Registry()
        obs.metrics.set_default(reg)

    from . import (alg1_latency, chaos, fig3_ccp_convergence,
                   fig4_convergence_cost, fig5_mislabel, fig6_availability,
                   lemma3_bound, roofline)

    benches = [
        ("alg1", alg1_latency.run),
        ("fig3", fig3_ccp_convergence.run),
        ("lemma3", lemma3_bound.run),
        ("roofline", roofline.run),
        ("chaos", chaos.run),
    ]
    if not args.fast:
        benches += [
            ("fig4", fig4_convergence_cost.run),
            ("fig5", fig5_mislabel.run),
            ("fig6", fig6_availability.run),
        ]
    if args.only:
        keep = set(args.only.split(","))
        benches = [b for b in benches if b[0] in keep]

    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches:
        try:
            fn()
        except Exception as e:  # keep the harness going
            failed.append(name)
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)

    if tele is not None:
        from repro import obs

        obs.set_default(None)
        tele.close()
        obs.emit_summary(obs.summarize(tele.events))
        print(f"trace -> {args.trace}; view it with "
              f"`python -m repro.obs export {args.trace}` (Perfetto) or "
              f"`python -m repro.obs dash {args.trace}`", file=sys.stderr)
    if reg is not None:
        from repro import obs

        obs.metrics.set_default(None)
        with open(args.metrics, "w") as f:
            f.write(reg.render())
        print(f"metrics exposition -> {args.metrics}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
