"""Paper Fig. 5: effect of the mislabeled proportion on test accuracy
(fixed training length).  Proposed vs baseline 4 (the strongest
baseline: all data + best RB)."""
from __future__ import annotations

import os

from .common import emit, run_scheme, save_json


def run(rounds: int | None = None, props=(0.0, 0.2, 0.4)):
    rounds = rounds or int(os.environ.get("REPRO_FIG5_ROUNDS", "40"))
    results = {}
    for prop in props:
        for scheme in ("proposed", "baseline4"):
            r = run_scheme(scheme, rounds, mislabel_prop=prop)
            results[f"{scheme}@{prop}"] = r
            emit(f"fig5_{scheme}_p{prop}", r["us_per_round"],
                 f"acc={r['final_acc']:.3f}")
    save_json("fig5_mislabel.json", results)
    return results


if __name__ == "__main__":
    run()
