"""Benchmark regression gate for the FEEL round loop.

Runs the standard small configuration with full instrumentation
(telemetry + metrics + convergence monitor + kernel profiling), writes
``BENCH_feel_round.json`` — per-stage p50/p95 latencies, roofline
utilization per stage, solver counters, and the Lemma-2 bound-gap
ratio — and compares it against a committed baseline:

    PYTHONPATH=src python -m benchmarks.regress                # gate
    PYTHONPATH=src python -m benchmarks.regress --update-baseline
    PYTHONPATH=src python -m benchmarks.regress --trace t.jsonl

Exit status is nonzero on regression (CI runs this non-blocking; see
.github/workflows/ci.yml ``bench-regress``).  What counts as a
regression:

* a stage's p50/p95 grew past ``--latency-tol`` x baseline (plus a
  millisecond-scale absolute floor, so micro-stages don't flap);
* a solver counter (swaps, CCP iterations, GP steps, infeasible calls)
  grew past ``--counter-tol`` — these are deterministic for a fixed
  seed, so growth means the algorithms are doing more work;
* the bound-gap ratio (max observed gap / Lemma-2 predicted bound)
  grew past ``--ratio-tol`` x baseline, or new bound violations
  appeared — the implementation stopped tracking the theory.

Latency comparisons exclude round 0 (jit compilation) and only fail on
*increases*; a faster run always passes.  Refresh the baseline with
``--update-baseline`` after an intentional change and commit the file.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import types
from typing import Dict, List, Optional

import numpy as np

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "BENCH_feel_round.json")

#: the gate's fixed small config — change it only together with
#: ``--update-baseline`` (the baseline records it and compare() refuses
#: to diff across configs).
CONFIG = {"K": 6, "N": 4, "Q": 2, "side": 8, "per_device": 50,
          "d_hat": 16, "gp_steps": 50, "mislabel_prop": 0.1, "seed": 0}


def run_gate(rounds: int = 12, trace_path: Optional[str] = None) -> Dict:
    """Run the instrumented small config; return the BENCH record."""
    import jax

    from repro import obs
    from repro.core import default_system
    from repro.data import SyntheticImages, non_iid_split
    from repro.fed import FEELConfig, FEELTrainer
    from repro.models import cnn

    c = CONFIG
    train = SyntheticImages.make(c["per_device"] * c["K"], side=c["side"],
                                 seed=0)
    test = SyntheticImages.make(100, side=c["side"], seed=1)
    data = non_iid_split(train, test, K=c["K"], per_device=c["per_device"],
                         mislabel_prop=c["mislabel_prop"], seed=c["seed"])
    sys_ = default_system(K=c["K"], N=c["N"], Q=c["Q"], D_hat=c["d_hat"])
    cfg = FEELConfig(scheme="proposed", d_hat=c["d_hat"],
                     gp_steps=c["gp_steps"], eval_every=max(rounds, 1),
                     seed=c["seed"])
    cc = cnn.CNNConfig(side=c["side"])
    params = cnn.init(jax.random.PRNGKey(c["seed"]), cc)
    model = types.SimpleNamespace(features=cnn.features, apply=cnn.apply,
                                  loss_fn=cnn.loss_fn,
                                  accuracy=cnn.accuracy)

    reg = obs.Registry()
    obs.metrics.set_default(reg)
    tele = obs.Telemetry(path=trace_path, profile=True,
                         meta={"source": "benchmarks.regress",
                               "config": c, "rounds": rounds})
    # straggler detection is wall-clock dependent; keep the gate's
    # counters deterministic for a fixed seed by disabling it here.
    mc = obs.MonitorConfig(beta=1.0, straggler_factor=float("inf"))
    monitor = obs.ConvergenceMonitor(sys_, mc, telemetry=tele, registry=reg)
    try:
        trainer = FEELTrainer(sys_, data, model, params, cfg,
                              telemetry=tele, monitor=monitor)
        trainer.run(rounds)
    finally:
        obs.metrics.set_default(None)
        tele.close()

    # -- per-stage latencies, round 0 (compilation) excluded -----------
    stage_durs: Dict[str, List[float]] = {}
    for e in tele.events:
        if isinstance(e, obs.StageEvent) and (e.round or 0) >= 1:
            stage_durs.setdefault(e.stage, []).append(e.dur_s)
    profiles = {e.stage: e for e in tele.events
                if isinstance(e, obs.ProfileEvent)}
    stages = {}
    for name, durs in sorted(stage_durs.items()):
        rec = {"calls": len(durs),
               "p50_ms": float(np.percentile(durs, 50) * 1e3),
               "p95_ms": float(np.percentile(durs, 95) * 1e3),
               "total_s": float(np.sum(durs)),
               "utilization": None}
        prof = profiles.get(name)
        if prof is not None and prof.peak_flops > 0:
            mean_s = float(np.mean(durs))
            rec["utilization"] = prof.flops / mean_s / prof.peak_flops
            rec["flops"] = prof.flops
            rec["bytes_accessed"] = prof.bytes_accessed
        stages[name] = rec

    # -- solver counters from the registry (deterministic per seed) ----
    counters = {}
    for fam in reg.snapshot():
        if fam["type"] != "counter":
            continue
        for s in fam["samples"]:
            labels = s.get("labels") or {}
            key = fam["name"]
            if labels:
                key += "{" + ",".join(f"{k}={v}" for k, v in
                                      sorted(labels.items())) + "}"
            counters[key] = s["value"]

    msum = monitor.summary()
    return {"bench": "feel_round", "config": dict(c), "rounds": rounds,
            "stages": stages, "solvers": counters,
            "bound_gap_ratio": msum["bound_gap_ratio"],
            "violations": msum["violations"]}


def compare(cur: Dict, base: Dict, latency_tol: float = 1.75,
            counter_tol: float = 0.10, ratio_tol: float = 1.5
            ) -> List[str]:
    """Return human-readable regression messages (empty = pass)."""
    fails: List[str] = []
    if cur.get("config") != base.get("config"):
        return [f"config changed ({cur.get('config')} vs baseline "
                f"{base.get('config')}) — rerun with --update-baseline"]

    for name, b in base.get("stages", {}).items():
        c = cur.get("stages", {}).get(name)
        if c is None:
            fails.append(f"stage {name!r} missing from current run")
            continue
        for q, floor_ms, tol in (("p50_ms", 1.0, latency_tol),
                                 ("p95_ms", 2.0, latency_tol * 1.5)):
            if c[q] > b[q] * tol + floor_ms:
                fails.append(f"stage {name}.{q}: {c[q]:.2f}ms > "
                             f"{tol:g}x baseline {b[q]:.2f}ms")

    for key, bv in base.get("solvers", {}).items():
        cv = cur.get("solvers", {}).get(key)
        if cv is None:
            fails.append(f"counter {key} missing from current run")
        elif cv > bv * (1.0 + counter_tol) + 1e-9:
            fails.append(f"counter {key}: {cv:g} > baseline {bv:g} "
                         f"(+{counter_tol:.0%} tol)")

    br, cr = base.get("bound_gap_ratio"), cur.get("bound_gap_ratio")
    if br is not None and cr is not None and cr > br * ratio_tol + 0.05:
        fails.append(f"bound_gap_ratio: {cr:.3f} > {ratio_tol:g}x "
                     f"baseline {br:.3f}")
    bviol = (base.get("violations") or {}).get("bound_violation", 0)
    cviol = (cur.get("violations") or {}).get("bound_violation", 0)
    if cviol > bviol:
        fails.append(f"bound violations: {cviol} > baseline {bviol}")
    return fails


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--out", default="BENCH_feel_round.json")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the baseline instead of comparing")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also write the telemetry JSONL trace")
    ap.add_argument("--latency-tol", type=float, default=1.75)
    ap.add_argument("--counter-tol", type=float, default=0.10)
    ap.add_argument("--ratio-tol", type=float, default=1.5)
    args = ap.parse_args(argv)

    cur = run_gate(rounds=args.rounds, trace_path=args.trace)
    with open(args.out, "w") as f:
        json.dump(cur, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")

    if args.update_baseline:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(cur, f, indent=1, sort_keys=True)
        print(f"baseline refreshed -> {args.baseline}")
        return

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; run with "
              f"--update-baseline to create one", file=sys.stderr)
        sys.exit(2)
    with open(args.baseline) as f:
        base = json.load(f)
    fails = compare(cur, base, latency_tol=args.latency_tol,
                    counter_tol=args.counter_tol,
                    ratio_tol=args.ratio_tol)
    for msg in fails:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    if fails:
        hint = args.trace or "head.jsonl"
        print(f"hint: attribute the regression with\n"
              f"  PYTHONPATH=src python -m benchmarks.regress "
              f"--update-baseline --trace base.jsonl   # on main\n"
              f"  PYTHONPATH=src python -m repro.obs diff base.jsonl "
              f"{hint}\n"
              f"which names the deepest span/solver responsible for "
              f"each delta", file=sys.stderr)
        sys.exit(1)
    print(f"PASS: no regression vs {args.baseline} "
          f"({len(base.get('stages', {}))} stages, "
          f"{len(base.get('solvers', {}))} counters)")


if __name__ == "__main__":
    main()
