"""Solver scaling benchmark: K in {8, 64, 256, 1024} devices.

Times the per-round decision stack — swap matching (Alg. 2), the final
power solve, CCP power (Alg. 3, bucketed inner solve) and data
selection (Algs. 4+5) — through the existing telemetry stages, for the
batched solver paths and (where affordable) the historical scalar
sweep:

    PYTHONPATH=src python -m benchmarks.scale                    # gate
    PYTHONPATH=src python -m benchmarks.scale --update-baseline
    PYTHONPATH=src python -m benchmarks.scale --check            # 5x
    PYTHONPATH=src python -m benchmarks.scale --ks 64 --trace t.jsonl

Modes (see docs/solvers.md):

* ``batched`` — vectorized sweep scoring every candidate move of a
  device in one closed-form evaluation (``core.matching._BatchScorer``)
  plus the chunked gradient projection; decisions are identical to the
  scalar path (tests/test_solver_equivalence.py), so only wall-clock
  differs.
* ``scalar`` — the per-candidate Python loop, run up to
  ``SCALAR_MAX_K`` devices (it is what the batched path is measured
  against; beyond that it is minutes per round).

CCP is benchmarked up to ``CCP_MAX_K`` on a fresh sparsity pattern per
rep, so its p50 reflects the bucketed retrace-free steady state, not
compilation.

``--check`` enforces the PR-10 acceptance bar: at K=256/N=32 the
batched matching+power+selection stages complete >= 5x faster than the
scalar path AND both modes return identical assignments.  The default
(gate) mode compares batched p50s against the committed
``benchmarks/baselines/BENCH_scale.json`` like benchmarks/regress.py —
latency growth past tolerance fails, faster always passes (CI runs it
non-blocking).
"""
from __future__ import annotations

import argparse
import json
import os
import sys as _sys
from typing import Dict, List, Optional

import numpy as np

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "BENCH_scale.json")

#: stages whose p50s the baseline tracks and ``--check`` sums.
STAGES = ("matching", "power", "selection")
KS_DEFAULT = (8, 64, 256, 1024)
#: largest K the scalar reference sweep is run at (O(K^2) Python calls
#: per sweep — beyond this it is minutes per round).
SCALAR_MAX_K = 256
#: largest K the CCP benchmark runs at (the Newton system is dense in
#: the K active variables).
CCP_MAX_K = 64
CONFIG = {"N": 32, "J": 50, "gp_steps": 100, "reps": 3, "seed": 0}


def _make_instance(K: int, rep: int, rng: np.random.Generator):
    """One round's (sys, h, alpha, sigma) at N=32, capacity == K."""
    import jax.numpy as jnp

    from repro.core import default_system

    N = CONFIG["N"]
    sys_ = default_system(K=K, N=N, Q=max(1, -(-K // N)))
    h = rng.gamma(2.0, 1e-5, size=(K, N))
    sigma = jnp.asarray(rng.gamma(2.0, 1.0, size=(K, CONFIG["J"])),
                        jnp.float32)
    alpha = np.ones(K)
    return sys_, h, alpha, sigma


def _stage_p50s(tele) -> Dict[str, float]:
    """Per-stage p50 latencies (ms), rep 0 (jit warmup) excluded."""
    from repro import obs

    durs: Dict[str, List[float]] = {}
    for e in tele.events:
        if isinstance(e, obs.StageEvent) and (e.round or 0) >= 1:
            durs.setdefault(e.stage, []).append(e.dur_s)
    return {name: float(np.percentile(v, 50) * 1e3)
            for name, v in sorted(durs.items())}


def bench_k(K: int, mode: str, reps: int,
            trace_path: Optional[str] = None) -> Dict:
    """Time ``reps + 1`` decision rounds at K devices in one mode.

    Returns stage p50s (warmup rep excluded), the summed
    matching+power+selection p50 total, solver counters, and the final
    rep's assignment (for the equivalence check).
    """
    import jax.numpy as jnp

    from repro import obs
    from repro.core import matching as matching_mod
    from repro.core import selection as selection_mod

    tele = obs.Telemetry(path=trace_path,
                         meta={"source": "benchmarks.scale", "K": K,
                               "mode": mode, "config": dict(CONFIG)})
    rng = np.random.default_rng(CONFIG["seed"])
    assign = None
    swaps = sweeps = rb_evals = 0
    try:
        for rep in range(reps + 1):
            tele.begin_round(rep)
            sys_, h, alpha, sigma = _make_instance(K, rep, rng)
            match = matching_mod.swap_matching(sys_, h, alpha, mode=mode,
                                               telemetry=tele)
            with tele.stage("selection"):
                tele.block(selection_mod.solve_selection(
                    sys_, sigma, jnp.ones_like(sigma),
                    steps=CONFIG["gp_steps"], telemetry=tele))
            assign = match.assign
            swaps, sweeps = match.swaps, match.sweeps
    finally:
        tele.close()
    p50s = _stage_p50s(tele)
    total = sum(p50s.get(s, 0.0) for s in STAGES)
    return {"stages": {s: p50s[s] for s in p50s if s in STAGES},
            "total_ms": total, "swaps": swaps, "sweeps": sweeps,
            "assign": assign}


def bench_ccp(K: int, reps: int) -> float:
    """p50 of the bucketed CCP solve over fresh sparsity patterns.

    Every rep re-matches a fresh channel draw, so each solve sees a new
    (k, n) active set — with bucketing these hit the cached Newton
    step, which is exactly the steady state the baseline should track.
    The first rep (compilation) is excluded.
    """
    import time

    import jax.numpy as jnp

    from repro.core import matching as matching_mod
    from repro.core import power as power_mod

    rng = np.random.default_rng(CONFIG["seed"] + 1)
    durs = []
    for rep in range(reps + 1):
        sys_, h, alpha, _ = _make_instance(K, rep, rng)
        match = matching_mod.swap_matching(sys_, h, alpha, mode="auto")
        t0 = time.perf_counter()
        power_mod.allocate_power(sys_, jnp.asarray(match.rho),
                                 jnp.asarray(h, jnp.float32),
                                 jnp.asarray(alpha, jnp.float32),
                                 method="ccp")
        if rep > 0:
            durs.append(time.perf_counter() - t0)
    return float(np.percentile(durs, 50) * 1e3)


def run_sweep(ks, reps: int, trace_path: Optional[str] = None,
              with_scalar: bool = True) -> Dict:
    sweep = {}
    for K in ks:
        rec: Dict = {}
        batched = bench_k(K, "batched", reps, trace_path=trace_path)
        assign_b = batched.pop("assign")
        rec["batched"] = batched
        if with_scalar and K <= SCALAR_MAX_K:
            scalar = bench_k(K, "scalar", reps)
            assign_s = scalar.pop("assign")
            rec["scalar"] = scalar
            rec["speedup"] = (scalar["total_ms"]
                              / max(batched["total_ms"], 1e-9))
            rec["decisions_equal"] = bool(
                np.array_equal(assign_b, assign_s))
        else:
            print(f"K={K}: scalar reference skipped "
                  f"(> SCALAR_MAX_K={SCALAR_MAX_K})")
        if K <= CCP_MAX_K:
            rec["ccp_p50_ms"] = bench_ccp(K, reps)
        line = (f"K={K}: batched {batched['total_ms']:.1f}ms"
                + (f", scalar {rec['scalar']['total_ms']:.1f}ms "
                   f"({rec['speedup']:.1f}x, decisions_equal="
                   f"{rec['decisions_equal']})" if "scalar" in rec else "")
                + (f", ccp {rec['ccp_p50_ms']:.1f}ms"
                   if "ccp_p50_ms" in rec else ""))
        print(line)
        sweep[str(K)] = rec
    return {"bench": "scale", "config": dict(CONFIG), "sweep": sweep}


def compare(cur: Dict, base: Dict, latency_tol: float = 2.0) -> List[str]:
    """Regression messages for the Ks present in the current run."""
    fails: List[str] = []
    if cur.get("config") != base.get("config"):
        return [f"config changed ({cur.get('config')} vs baseline "
                f"{base.get('config')}) — rerun with --update-baseline"]
    for K, c in cur.get("sweep", {}).items():
        b = base.get("sweep", {}).get(K)
        if b is None:
            fails.append(f"K={K} missing from baseline — rerun with "
                         f"--update-baseline")
            continue
        cb, bb = c["batched"], b["batched"]
        # floor scales with K: micro-stage jitter at K=8 must not flap
        floor = 1.0 + 0.01 * float(K)
        if cb["total_ms"] > bb["total_ms"] * latency_tol + floor:
            fails.append(f"K={K} batched total: {cb['total_ms']:.1f}ms > "
                         f"{latency_tol:g}x baseline "
                         f"{bb['total_ms']:.1f}ms")
        for cnt in ("swaps", "sweeps"):
            if cb[cnt] > bb[cnt]:
                fails.append(f"K={K} {cnt}: {cb[cnt]} > baseline "
                             f"{bb[cnt]} (deterministic per seed)")
        if b.get("decisions_equal") and not c.get("decisions_equal", True):
            fails.append(f"K={K}: batched and scalar assignments diverged")
    return fails


def check_acceptance(reps: int) -> List[str]:
    """The PR-10 bar: >=5x at K=256/N=32 with identical decisions."""
    rec = run_sweep([256], reps)["sweep"]["256"]
    fails = []
    if not rec.get("decisions_equal"):
        fails.append("K=256: batched and scalar assignments diverged")
    if rec.get("speedup", 0.0) < 5.0:
        fails.append(f"K=256: speedup {rec.get('speedup', 0):.2f}x < 5x")
    return fails


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ks", type=int, nargs="+", default=list(KS_DEFAULT))
    ap.add_argument("--reps", type=int, default=CONFIG["reps"])
    ap.add_argument("--out", default="BENCH_scale.json")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the baseline instead of comparing")
    ap.add_argument("--check", action="store_true",
                    help="enforce the >=5x @ K=256 acceptance bar")
    ap.add_argument("--no-scalar", action="store_true",
                    help="skip the scalar reference sweeps")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the batched runs' telemetry JSONL trace")
    ap.add_argument("--latency-tol", type=float, default=2.0)
    args = ap.parse_args(argv)

    if args.check:
        fails = check_acceptance(args.reps)
        for msg in fails:
            print(f"CHECK FAILED: {msg}", file=_sys.stderr)
        if fails:
            _sys.exit(1)
        print("PASS: batched solver >= 5x scalar at K=256/N=32 with "
              "identical decisions")
        return

    cur = run_sweep(args.ks, args.reps, trace_path=args.trace,
                    with_scalar=not args.no_scalar)
    with open(args.out, "w") as f:
        json.dump(cur, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")

    if args.update_baseline:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(cur, f, indent=1, sort_keys=True)
        print(f"baseline refreshed -> {args.baseline}")
        return
    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; run with "
              f"--update-baseline to create one", file=_sys.stderr)
        _sys.exit(2)
    with open(args.baseline) as f:
        base = json.load(f)
    fails = compare(cur, base, latency_tol=args.latency_tol)
    for msg in fails:
        print(f"REGRESSION: {msg}", file=_sys.stderr)
    if fails:
        _sys.exit(1)
    print(f"PASS: no regression vs {args.baseline} "
          f"({len(cur['sweep'])} configs)")


if __name__ == "__main__":
    main()
