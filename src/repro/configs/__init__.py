"""Assigned-architecture registry: ``get_config(name)`` / ``ARCHS``.

Every config cites its source in ``citation`` and carries the exact
dims from the assignment card.  ``smoke_config(name)`` returns the
reduced same-family variant used by per-arch smoke tests
(<= 2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ArchConfig

ARCHS: List[str] = [
    "qwen2-vl-2b",
    "deepseek-v3-671b",
    "deepseek-v2-236b",
    "stablelm-12b",
    "command-r-35b",
    "recurrentgemma-9b",
    "llama3_2-3b",
    "falcon-mamba-7b",
    "gemma3-12b",
    "musicgen-medium",
]

_ALIASES = {"llama3.2-3b": "llama3_2-3b"}


def _module(name: str):
    name = _ALIASES.get(name, name)
    return importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")


def get_config(name: str) -> ArchConfig:
    cfg = _module(name).CONFIG
    cfg.validate()
    return cfg


def smoke_config(name: str) -> ArchConfig:
    cfg = _module(name).smoke()
    cfg.validate()
    return cfg


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCHS}
