"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01].

40L, d_model=8192, 64 heads (GQA kv=8), d_ff=22528, vocab=256000.
No biases; rope theta 8e6 (long-context tuned).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", arch_type="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000,
    layer_pattern=("attn",), rope_theta=8e6,
    optimizer="adamw", citation="hf:CohereForAI/c4ai-command-r-v01",
)


def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                         d_ff=256, vocab=512)
