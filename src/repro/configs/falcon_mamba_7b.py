"""Falcon-Mamba-7B [arXiv:2410.05355].

64 attention-free Mamba-1 layers, d_model=4096, ssm_state=16,
expand=2 (d_inner=8192), conv=4, dt_rank=256, vocab=65024.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", arch_type="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024,
    layer_pattern=("mamba",), ffn_in_pattern=False,
    ssm_state=16, ssm_expand=2, ssm_conv=4,
    optimizer="adamw", citation="arXiv:2410.05355",
)


def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=2, d_model=128, vocab=512, ssm_state=8)
