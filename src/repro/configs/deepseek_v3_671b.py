"""DeepSeek-V3 671B [arXiv:2412.19437].

61L, d_model=7168, 128 heads, MLA (q_lora=1536, kv_lora=512,
qk_nope=128, qk_rope=64, v_head=128), vocab=129280.
MoE: 1 shared + 256 routed experts, top-8, per-expert d_ff=2048;
first 3 layers dense (d_ff=18432).  The MTP (multi-token-prediction)
auxiliary head is NOT reproduced (noted in DESIGN.md — it is a training
objective add-on, orthogonal to the FEEL integration studied here).
Optimizer: adafactor (Adam fp32 state would not fit 16 GB/chip HBM).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", arch_type="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab=129280,
    layer_pattern=("mla",), first_dense=3,
    n_experts=256, n_shared_experts=1, topk=8, moe_d_ff=2048,
    q_lora=1536, kv_lora=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    optimizer="adafactor", citation="arXiv:2412.19437",
)


def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                         d_ff=256, vocab=512, first_dense=1,
                         n_experts=4, topk=2, moe_d_ff=64,
                         q_lora=48, kv_lora=32, qk_nope_dim=16,
                         qk_rope_dim=8, v_head_dim=16,
                         capacity_factor=8.0)
