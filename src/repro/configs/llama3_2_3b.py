"""Llama-3.2-3B [hf:meta-llama/Llama-3.2-3B family card].

28L, d_model=3072, 24 heads (GQA kv=8), d_ff=8192, vocab=128256.
rope theta 500000 (llama3 long-context base).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b", arch_type="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=128256,
    layer_pattern=("attn",), rope_theta=5e5,
    optimizer="adamw", citation="hf:meta-llama/Llama-3.2-1B",
)


def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=2, d_model=96, n_heads=4, n_kv_heads=2,
                         d_ff=256, vocab=512)
