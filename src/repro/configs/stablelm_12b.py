"""StableLM-2-12B [hf:stabilityai/stablelm-2-1_6b family card].

40L, d_model=5120, 32 heads (GQA kv=8), d_ff=13824, vocab=100352.
Partial rotary embeddings (25% of head_dim), no biases.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", arch_type="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab=100352,
    layer_pattern=("attn",), rope_fraction=0.25, rope_theta=1e4,
    optimizer="adamw", citation="hf:stabilityai/stablelm-2-12b",
)


def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                         d_ff=256, vocab=512)
