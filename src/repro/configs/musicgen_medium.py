"""MusicGen-medium decoder [arXiv:2306.05284].

48L, d_model=1536, 24 heads (MHA kv=24, head_dim 64), d_ff=6144,
4 EnCodec codebooks of vocab 2048 (sum-embedding in, 4 LM heads out).
The conv codec frontend is the allowed stub; the token-space decoder
(incl. the delay-pattern training loss over 4 codebooks) is real.
Gated-GELU FFN replaces the original plain GELU (noted in DESIGN.md).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", arch_type="audio", modality="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048, n_codebooks=4,
    layer_pattern=("attn",), act="gelu", rope_theta=1e4,
    optimizer="adamw", citation="arXiv:2306.05284",
)


def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                         d_ff=256, vocab=128, n_codebooks=2)
