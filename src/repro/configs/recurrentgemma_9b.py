"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38L, d_model=4096, 16 heads (MQA kv=1, head_dim 256), d_ff=12288,
vocab=256000.  Pattern: (RG-LRU, RG-LRU, local-attention) — 1 attention
per 2 recurrent blocks; local window 2048.  12 full patterns + 2
remaining recurrent layers = 38.  lru_width follows d_model
(simplification vs the released 2560-wide LRU; noted in DESIGN.md).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", arch_type="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    layer_pattern=("rglru", "rglru", "attn_local"), window=2048,
    ssm_conv=4, rope_theta=1e4,
    optimizer="adamw", citation="arXiv:2402.19427",
)


def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=5, d_model=128, n_heads=4, n_kv_heads=1,
                         d_ff=256, vocab=512, head_dim=32, window=64)
