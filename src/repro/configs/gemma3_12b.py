"""Gemma-3-12B [hf:google/gemma-3-12b family card].

48L, d_model=3840, 16 heads (GQA kv=8, head_dim 256), d_ff=15360,
vocab=262144.  5 local (1024-window, theta 1e4) : 1 global (theta 1e6)
interleave; qk-norm; 128k context.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", arch_type="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab=262144, head_dim=256,
    layer_pattern=("attn_local",) * 5 + ("attn",), window=1024,
    rope_theta=1e6, rope_theta_local=1e4, qk_norm=True,
    optimizer="adamw", citation="hf:google/gemma-3-1b-pt",
)


def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=6, d_model=128, n_heads=4, n_kv_heads=2,
                         d_ff=256, vocab=512, head_dim=32, window=32)
