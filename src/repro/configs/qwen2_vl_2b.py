"""Qwen2-VL-2B language backbone [arXiv:2409.12191].

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936.
M-RoPE with (temporal, height, width) = (16, 24, 24) frequency-pair
sections over head_dim=128; dynamic-resolution patches arrive as
precomputed embeddings (the ViT frontend is the allowed stub).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", arch_type="vlm", modality="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, head_dim=128,
    layer_pattern=("attn",), rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    optimizer="adamw", citation="arXiv:2409.12191",
)


def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                         d_ff=256, vocab=512, head_dim=32,
                         mrope_sections=(4, 6, 6))
