"""DeepSeek-V2 236B [arXiv:2405.04434].

60L, d_model=5120, 128 heads, MLA (q_lora=3072, kv_lora=512), vocab=102400.
MoE: 2 shared + 160 routed experts, top-6, per-expert d_ff=1536;
first layer dense (d_ff=12288).  Optimizer: adafactor (HBM).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", arch_type="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400,
    layer_pattern=("mla",), first_dense=1,
    n_experts=160, n_shared_experts=2, topk=6, moe_d_ff=1536,
    q_lora=3072, kv_lora=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    optimizer="adafactor", citation="arXiv:2405.04434",
)


def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                         d_ff=256, vocab=512, first_dense=1,
                         n_experts=4, topk=2, moe_d_ff=64,
                         q_lora=48, kv_lora=32, qk_nope_dim=16,
                         qk_rope_dim=8, v_head_dim=16,
                         n_shared_experts=1, capacity_factor=8.0)
