"""Seeded, deterministic fault injection for FEEL rounds.

The paper's system model already admits unreliability — availability is
Bernoulli (``alpha_k ~ Bern(eps_k)``, Lemma 1) and channels fade every
round — but those draws happen *before* the server fixes the round
decision.  This module injects the failures that happen *after* the
allocation was fixed, which is where a deployed FEEL system actually
breaks:

* **dropout** — a scheduled device vanishes mid-round and its upload
  never arrives (post-matching, unlike the pre-matching ``alpha``);
* **straggler** — an upload arrives, but later than the eq. (8)+(16)
  latency model predicts (an extra exponential delay on top of
  ``tau_k + T``);
* **nan_upload** — the upload arrives corrupted: every gradient leaf of
  that device is replaced with NaN;
* **solver_fail** — the round's matching (Alg. 2) or power (Alg. 3)
  solve is forced to fail so the fallback chain in ``core/joint.py``
  gets exercised.

Determinism and replay
----------------------
Every draw is keyed by ``(spec.seed, round)`` — and, for retry delays,
``(spec.seed, round, device, attempt)`` — through independent
``np.random.SeedSequence`` streams.  Faults for round *i* therefore do
not depend on call order or on how many other rounds were queried,
which is what makes ``FEELTrainer.resume()`` replay the exact same
faults after a crash.  A plan is fully described by its ``FaultSpec``;
``FaultSpec.to_dict()``/``from_dict`` round-trip through JSON so a
chaos run can be replayed from its trace header.

The plan is pure host-side numpy and never touches the trainer's RNG
streams: a plan whose probabilities are all zero (or ``faults=None``)
leaves the training trajectory bit-for-bit identical.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

__all__ = ["FaultSpec", "RoundFaults", "FaultPlan", "CHAOS_SPEC"]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative description of a fault plan (all rates per round).

    ``dropout_prob``/``straggler_prob``/``nan_prob`` are per-device
    Bernoulli rates applied to devices that would otherwise upload;
    ``straggler_delay_s`` is the mean of the exponential extra delay a
    straggling upload suffers; ``matching_fail_prob`` and
    ``power_fail_prob`` force the round's solver calls to fail.
    ``start_round``/``stop_round`` bound the window in which faults
    fire (``stop_round=None`` means forever).
    """

    seed: int = 0
    dropout_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_delay_s: float = 0.25
    nan_prob: float = 0.0
    matching_fail_prob: float = 0.0
    power_fail_prob: float = 0.0
    start_round: int = 0
    stop_round: Optional[int] = None

    def enabled_at(self, i: int) -> bool:
        if i < self.start_round:
            return False
        return self.stop_round is None or i < self.stop_round

    @property
    def any_rate(self) -> float:
        return max(self.dropout_prob, self.straggler_prob, self.nan_prob,
                   self.matching_fail_prob, self.power_fail_prob)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultSpec":
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"unknown FaultSpec fields: {sorted(unknown)}")
        return cls(**d)


#: the aggressive preset the CI ``chaos`` job runs (30% dropout,
#: stragglers, NaN uploads, forced solver failures).
CHAOS_SPEC = FaultSpec(seed=0, dropout_prob=0.3, straggler_prob=0.3,
                       straggler_delay_s=0.5, nan_prob=0.15,
                       matching_fail_prob=0.2, power_fail_prob=0.2)


@dataclasses.dataclass(frozen=True)
class RoundFaults:
    """The materialized faults for one round (arrays of length K)."""

    round: int
    dropout: np.ndarray          # (K,) bool: upload silently lost
    straggler: np.ndarray        # (K,) bool: upload delayed
    delay_s: np.ndarray          # (K,) float: extra delay (0 if not)
    nan_upload: np.ndarray       # (K,) bool: upload corrupted to NaN
    fail_matching: bool          # force Alg. 2 to fail this round
    fail_power: bool             # force Alg. 3 / power solve to fail

    def any(self) -> bool:
        return bool(self.dropout.any() or self.straggler.any()
                    or self.nan_upload.any() or self.fail_matching
                    or self.fail_power)


def _round_rng(seed: int, *key: int) -> np.random.Generator:
    """Independent stream keyed by (seed, *key) — call-order free."""
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=tuple(key)))


class FaultPlan:
    """Replayable fault schedule: ``for_round(i, K)`` is a pure
    function of ``(spec, i, K)``."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultPlan":
        return cls(FaultSpec.from_dict(d))

    def to_dict(self) -> Dict[str, Any]:
        return self.spec.to_dict()

    # ------------------------------------------------------------------
    def for_round(self, i: int, K: int) -> RoundFaults:
        s = self.spec
        if not s.enabled_at(i) or s.any_rate <= 0.0:
            z = np.zeros(K, bool)
            return RoundFaults(round=i, dropout=z, straggler=z,
                               delay_s=np.zeros(K), nan_upload=z,
                               fail_matching=False, fail_power=False)
        rng = _round_rng(s.seed, i)
        # fixed draw order => the same spec always yields the same plan
        dropout = rng.random(K) < s.dropout_prob
        straggler = rng.random(K) < s.straggler_prob
        delay = rng.exponential(max(s.straggler_delay_s, 1e-12), K)
        nan_upload = rng.random(K) < s.nan_prob
        fail_matching = bool(rng.random() < s.matching_fail_prob)
        fail_power = bool(rng.random() < s.power_fail_prob)
        # a dropped upload never arrives, so it cannot also straggle or
        # corrupt; keeping the classes disjoint makes accounting exact
        straggler &= ~dropout
        nan_upload &= ~dropout
        return RoundFaults(round=i, dropout=dropout, straggler=straggler,
                           delay_s=np.where(straggler, delay, 0.0),
                           nan_upload=nan_upload,
                           fail_matching=fail_matching,
                           fail_power=fail_power)

    def retry_delay_s(self, i: int, k: int, attempt: int) -> float:
        """Extra delay of device ``k``'s ``attempt``-th retry in round
        ``i``.  With probability ``straggler_prob`` the retry straggles
        again (fresh exponential delay), otherwise it is prompt."""
        s = self.spec
        if not s.enabled_at(i) or s.straggler_prob <= 0.0:
            return 0.0
        rng = _round_rng(s.seed, i, k + 1, attempt)
        if rng.random() >= s.straggler_prob:
            return 0.0
        return float(rng.exponential(max(s.straggler_delay_s, 1e-12)))
