from .client import local_gradient, per_sample_sigma
from .server import aggregate_gradients
from .rounds import FEELConfig, FEELTrainer, RoundMetrics

__all__ = ["local_gradient", "per_sample_sigma", "aggregate_gradients",
           "FEELConfig", "FEELTrainer", "RoundMetrics"]
