from .client import local_gradient, per_sample_sigma
from .faults import CHAOS_SPEC, FaultPlan, FaultSpec, RoundFaults
from .server import aggregate_gradients, ipw_mass, ipw_weights
from .rounds import (FEELConfig, FEELTrainer, ResilienceConfig,
                     RoundMetrics)

__all__ = ["local_gradient", "per_sample_sigma", "aggregate_gradients",
           "ipw_mass", "ipw_weights",
           "FEELConfig", "FEELTrainer", "RoundMetrics",
           "ResilienceConfig", "FaultSpec", "FaultPlan", "RoundFaults",
           "CHAOS_SPEC"]
