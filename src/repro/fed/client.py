"""Device-side computation (paper §II-B).

* ``per_sample_sigma`` — sigma_{k,j} = ||g_{k,j}||^2 for every sample of
  the sampled sub-dataset D̂_k.  Two modes:
    - "full": vmap(grad) over samples — the literal paper quantity;
    - "last_layer": exact gradient-norm of the *output layer only*:
      for a linear head  logits = h W + b  with CE loss,
        dL/dW_j = h_j^T (p_j - y_j),  dL/db_j = (p_j - y_j)
      so ||g_j||^2 = ||p_j - y_j||^2 * (||h_j||^2 + 1).
      O(batch * d) instead of O(batch * |params|); this is the scorer
      the large-model path uses (see kernels/gradnorm for the fused
      TPU version).
* ``local_gradient`` — eq. (4): gradient of the loss averaged over the
  *selected* subset M_k (selection mask delta).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def _head_residuals(params, images: Array, labels: Array,
                    features_fn: Callable) -> tuple[Array, Array]:
    """(features h, logit residuals p - y) of the linear head."""
    h, logits = features_fn(params, images)
    p = jax.nn.softmax(logits)
    y = jax.nn.one_hot(labels, logits.shape[-1], dtype=p.dtype)
    return h, p - y


def per_sample_sigma(params, images: Array, labels: Array,
                     features_fn: Callable, method: str = "last_layer",
                     loss_fn: Callable | None = None) -> Array:
    """sigma for each sample: (B,)."""
    if method == "last_layer":
        h, d = _head_residuals(params, images, labels, features_fn)
        return jnp.sum(d * d, axis=-1) * (jnp.sum(h * h, axis=-1) + 1.0)
    if method == "full":
        assert loss_fn is not None

        def one(img, lab):
            g = jax.grad(loss_fn)(params, img[None], lab[None])
            return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g))

        return jax.vmap(one)(images, labels)
    raise ValueError(f"unknown sigma method: {method}")


def batched_sigma(params, images: Array, labels: Array,
                  features_fn: Callable) -> Array:
    """All-device "last_layer" sigma in one fused pass: (K, D̂).

    Flattens the (K, D̂, ...) round batch to one (K*D̂, ...) forward
    pass and scores it with the tiled row-norm kernel
    (``kernels.gradnorm.gradnorm_sigma``) instead of K per-device
    elementwise reductions — the batched sigma path of the scale
    benchmark (``fed.rounds`` selects it for
    ``sigma_method="last_layer_kernel"``).  Equal to the vmapped
    "last_layer" scores up to float32 reduction order.
    """
    from ..kernels import gradnorm as gradnorm_mod

    K, D = labels.shape[:2]
    flat = images.reshape((K * D,) + images.shape[2:])
    h, d = _head_residuals(params, flat, labels.reshape(-1), features_fn)
    return gradnorm_mod.gradnorm_sigma(h, d).reshape(K, D)


def local_gradient(params, images: Array, labels: Array, delta: Array,
                   loss_fn: Callable):
    """eq. (4): grad of (sum_j delta_j l_j) / (sum_j delta_j)."""

    def weighted_loss(p):
        logits_loss = _per_sample_loss(p, images, labels, loss_fn)
        return (jnp.sum(delta * logits_loss)
                / jnp.maximum(jnp.sum(delta), 1e-9))

    return jax.grad(weighted_loss)(params)


def _per_sample_loss(params, images, labels, loss_fn):
    """Vectorized per-sample losses via a batched loss_fn contract:
    loss_fn(params, images, labels) returns the mean loss, so we call
    it per sample through vmap."""
    return jax.vmap(lambda img, lab: loss_fn(params, img[None],
                                             lab[None]))(images, labels)
