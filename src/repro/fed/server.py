"""Server-side aggregation (paper §II-D).

eq. (19): g_hat = (1/|D̂|) sum_k (|D̂_k|/eps_k) * alpha_k * g_k.
Lemma 1: unbiased under alpha_k ~ Bernoulli(eps_k) (tested in
tests/test_fed.py by Monte-Carlo).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.types import SystemParams

Array = jax.Array


def aggregate_gradients(sys: SystemParams, local_grads, alpha: Array):
    """``local_grads``: pytree with a leading K axis on every leaf."""
    w = (sys.D_hat / sys.eps) * alpha / sys.D_hat_total  # (K,)

    def agg(leaf):
        return jnp.tensordot(w.astype(leaf.dtype), leaf, axes=(0, 0))

    return jax.tree.map(agg, local_grads)
