"""Server-side aggregation (paper §II-D).

eq. (19): g_hat = (1/|D̂|) sum_k (|D̂_k|/eps_k) * alpha_k * g_k.
Lemma 1: unbiased under alpha_k ~ Bernoulli(eps_k) (tested in
tests/test_fed.py by Monte-Carlo).

Robustness extensions (docs/robustness.md):

* ``eps_k == 0`` is guarded — such a device can never be available, so
  its IPW term is defined as 0 instead of the 0/0 NaN the raw formula
  produces (which would silently poison the whole aggregate);
* ``renormalize=True`` divides by the *realized* IPW mass of the
  surviving uploads instead of the planned ``|D̂|`` total.  When a
  device drops out *after* the allocation was fixed (mid-round fault,
  straggler timeout, quarantine), plain eq. (19) under-scales the step;
  renormalizing keeps g_hat a convex combination of the surviving local
  gradients, so its direction stays consistent with the survivor set.
  With no survivors the result is an all-zeros tree — callers should
  check ``ipw_mass`` first and skip the optimizer update entirely
  (``FEELTrainer`` does).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.types import SystemParams

Array = jax.Array


def ipw_weights(sys: SystemParams, alpha: Array) -> Array:
    """Unnormalized eq.-(19) weights |D̂_k|/eps_k * alpha_k, with the
    eps_k == 0 guard (weight 0, not NaN)."""
    eps_safe = jnp.where(sys.eps > 0, sys.eps, 1.0)
    live = (sys.eps > 0).astype(alpha.dtype)
    return (sys.D_hat / eps_safe) * alpha * live


def ipw_mass(sys: SystemParams, alpha: Array) -> float:
    """Total realized IPW weight of ``alpha``; 0.0 means no usable
    upload survived and the optimizer update should be skipped."""
    return float(jnp.sum(ipw_weights(sys, alpha)))


def aggregate_gradients(sys: SystemParams, local_grads, alpha: Array,
                        renormalize: bool = False):
    """``local_grads``: pytree with a leading K axis on every leaf."""
    w = ipw_weights(sys, alpha)
    if renormalize:
        denom = jnp.sum(w)
        w = jnp.where(denom > 0, w / jnp.where(denom > 0, denom, 1.0), 0.0)
    else:
        w = w / sys.D_hat_total

    def agg(leaf):
        return jnp.tensordot(w.astype(leaf.dtype), leaf, axes=(0, 0))

    return jax.tree.map(agg, local_grads)
