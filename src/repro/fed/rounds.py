"""The FEEL communication-round loop (paper §II + Algorithm 1).

Each round:
  1. every device samples |D̂_k| local samples and scores them
     (sigma_{k,j} = per-sample gradient-norm^2);
  2. channels h_{k,n} and availability alpha_k are drawn;
  3. the server runs Algorithm 1 (or a baseline scheme) to fix
     (rho*, p*, delta*) and is billed the net cost (eq. 18);
  4. devices compute local gradients on their *selected* samples
     (eq. 4) — FedSGD; with ``local_steps > 1`` the FedAvg variant of
     footnote 4 runs multiple local steps and uploads model deltas;
  5. the server aggregates with inverse-propensity weights (eq. 19)
     and applies the optimizer update (eq. 20; Adam in §VI-A).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs, optim
from ..obs import metrics as metrics_mod
from ..core import cost as cost_mod
from ..core import joint as joint_mod
from ..core.types import RoundState, SystemParams
from ..data.federated import FederatedDataset
from . import client as client_mod
from . import server as server_mod

Array = jax.Array


@dataclasses.dataclass
class FEELConfig:
    scheme: str = "proposed"          # proposed | baseline1..baseline4
    selection_method: str = "faithful"  # faithful (Alg 4+5) | exact
    sigma_method: str = "last_layer"    # last_layer | full
    power_evaluator: str = "closed_form"  # closed_form | ccp
    optimizer: str = "adam"
    lr: float = 1e-3
    d_hat: int = 200
    local_steps: int = 1              # >1 => FedAvg variant
    gp_steps: int = 400
    gp_step0: float = 0.3
    warmup_rounds: int = 0    # select ALL samples first (beyond-paper fix:
                              # sigma is uninformative before the model fits)
    eval_every: int = 10
    seed: int = 0


@dataclasses.dataclass
class RoundMetrics:
    round: int
    net_cost: float
    cum_net_cost: float
    delta_obj: float
    n_selected: int
    n_uploaded: int
    frac_mislabeled_selected: float
    test_acc: Optional[float] = None


class FEELTrainer:
    """Drives FEEL rounds for an image-classification model."""

    def __init__(self, sys: SystemParams, data: FederatedDataset,
                 model, params, cfg: FEELConfig,
                 telemetry: Optional[obs.NullTelemetry] = None,
                 monitor: Optional["obs.ConvergenceMonitor"] = None):
        """``model`` exposes features(params, x), apply, loss_fn, accuracy.

        ``telemetry``: an ``obs`` sink for the round-level trace; the
        default (``None``) resolves to the process-wide sink, which is
        a no-op unless e.g. ``benchmarks/run.py --trace`` installed one.

        ``monitor``: an ``obs.ConvergenceMonitor`` fed one observation
        per round (training-loss gap proxy, ||g_hat||^2, step size, the
        decision's Delta term, wall/stage timings).  ``None`` (default)
        skips every monitor code path — round outputs stay bit-for-bit
        identical.  Metrics flow to the process-default registry
        (``obs.metrics.set_default``), also a no-op unless installed.
        """
        self.sys = sys
        self.data = data
        self.model = model
        self.params = params
        self.cfg = cfg
        self.obs = obs.resolve(telemetry)
        self.monitor = monitor
        self._profiled: set = set()
        self.rng = np.random.default_rng(cfg.seed)
        self.key = jax.random.PRNGKey(cfg.seed)
        opt_builder = {"adam": optim.adam, "sgd": optim.sgd,
                       "momentum": optim.momentum,
                       "adafactor": optim.adafactor}[cfg.optimizer]
        self.opt = opt_builder(cfg.lr)
        self.opt_state = self.opt.init(params)
        self._build_jitted()

    # ------------------------------------------------------------------
    def _build_jitted(self):
        model, cfg = self.model, self.cfg

        @jax.jit
        def sigma_all(params, images, labels):
            """(K, D̂) sigma scores."""
            f = functools.partial(client_mod.per_sample_sigma,
                                  features_fn=model.features,
                                  method=cfg.sigma_method,
                                  loss_fn=model.loss_fn)
            return jax.vmap(lambda im, lb: f(params, im, lb))(images, labels)

        @jax.jit
        def local_grads(params, images, labels, delta):
            """pytree with leading K axis (FedSGD local gradients)."""
            return jax.vmap(
                lambda im, lb, dl: client_mod.local_gradient(
                    params, im, lb, dl, model.loss_fn))(images, labels,
                                                        delta)

        @jax.jit
        def local_deltas(params, images, labels, delta, lr):
            """FedAvg: run local_steps SGD steps, return param deltas."""

            def one_device(im, lb, dl):
                def step(p, _):
                    g = client_mod.local_gradient(p, im, lb, dl,
                                                  model.loss_fn)
                    p = jax.tree.map(lambda a, b: a - lr * b, p, g)
                    return p, None

                p_out, _ = jax.lax.scan(step, params, None,
                                        length=cfg.local_steps)
                # pseudo-gradient: (w - w_k') / lr, aggregated like a grad
                return jax.tree.map(lambda a, b: (a - b) / lr, params, p_out)

            return jax.vmap(one_device)(images, labels, delta)

        if self.obs.annotate:
            # optional jax.profiler trace annotations: the jitted round
            # computations show up named in TensorBoard/Perfetto traces
            sigma_all = obs.annotate_fn(sigma_all, "repro.sigma_all")
            local_grads = obs.annotate_fn(local_grads, "repro.local_grads")
            local_deltas = obs.annotate_fn(local_deltas,
                                           "repro.local_deltas")

        self._sigma_all = sigma_all
        self._local_grads = local_grads
        self._local_deltas = local_deltas

    # ------------------------------------------------------------------
    def _gather_round_batches(self):
        idx = self.data.sample_subsets(self.rng, self.cfg.d_hat)
        imgs = np.stack([self.data.device_images[k][idx[k]]
                         for k in range(self.sys.K)])
        labels = np.stack([self.data.device_labels[k][idx[k]]
                           for k in range(self.sys.K)])
        true = np.stack([self.data.device_true[k][idx[k]]
                         for k in range(self.sys.K)])
        return jnp.asarray(imgs), jnp.asarray(labels), true


    def run_round(self, i: int, eval_now: bool = False) -> RoundMetrics:
        sys, cfg, tele = self.sys, self.cfg, self.obs
        t_round = time.perf_counter()
        tele.begin_round(i)
        ev0 = len(tele.events) if tele.enabled else 0

        with tele.stage("data"):
            images, labels, true = self._gather_round_batches()
        self.key, kh, ka, kb = jax.random.split(self.key, 4)

        if tele.profile:
            self._profile_once("sigma_all", "sigma", self._sigma_all,
                               (self.params, images, labels), tele, i)
        with tele.stage("sigma"):
            sigma = tele.block(self._sigma_all(self.params, images, labels))
        h = jax.random.exponential(kh, (sys.K, sys.N)) * 1e-5
        alpha = (jax.random.uniform(ka, (sys.K,)) < sys.eps
                 ).astype(jnp.float32)
        mask = jnp.ones_like(sigma)
        state = RoundState(h=h, alpha=alpha, sigma=sigma, sigma_mask=mask)

        if cfg.scheme == "proposed" and i < cfg.warmup_rounds:
            # warmup: resource allocation as proposed, selection = all
            match = joint_mod.matching_mod.swap_matching(
                sys, state.h, state.alpha,
                evaluator=cfg.power_evaluator, telemetry=tele)
            with tele.stage("selection"):
                pass  # warmup selects everything; keep the stage present
            dec = joint_mod._finish(sys, match.rho, match.p,
                                    np.asarray(mask), state,
                                    feasible=match.feasible,
                                    swaps=match.swaps, telemetry=tele)
        elif cfg.scheme == "proposed":
            dec = joint_mod.proposed_scheme(
                sys, state, selection_method=cfg.selection_method,
                power_evaluator=cfg.power_evaluator, gp_steps=cfg.gp_steps,
                gp_step0=cfg.gp_step0, telemetry=tele)
        elif cfg.scheme.startswith("baseline"):
            dec = joint_mod.baseline_scheme(sys, state,
                                            int(cfg.scheme[-1]), key=kb,
                                            telemetry=tele)
        else:
            raise ValueError(cfg.scheme)

        delta = jnp.asarray(dec.delta)
        matched = jnp.asarray(dec.rho.sum(axis=1) > 0, jnp.float32)
        uploaded = alpha * matched

        gap_proxy = None
        if self.monitor is not None:
            # mean training loss on the round batch under the PRE-update
            # params: the Lemma-2 gap proxy (L* offset cancels, see
            # repro.obs.monitor).  Read-only — numerics are untouched.
            flat_im = images.reshape((-1,) + images.shape[2:])
            gap_proxy = float(self.model.loss_fn(self.params, flat_im,
                                                 labels.reshape(-1)))

        if tele.profile:
            if cfg.local_steps > 1:
                self._profile_once(
                    "local_deltas", "local_grads", self._local_deltas,
                    (self.params, images, labels, delta,
                     jnp.asarray(cfg.lr)), tele, i)
            else:
                self._profile_once(
                    "local_grads", "local_grads", self._local_grads,
                    (self.params, images, labels, delta), tele, i)
        with tele.stage("local_grads"):
            if cfg.local_steps > 1:
                grads = self._local_deltas(self.params, images, labels,
                                           delta, jnp.asarray(cfg.lr))
            else:
                grads = self._local_grads(self.params, images, labels,
                                          delta)
            grads = tele.block(grads)

        g_norm_sq = None
        with tele.stage("aggregate"):
            g_hat = server_mod.aggregate_gradients(sys, grads, uploaded)
            if self.monitor is not None:
                g_norm_sq = float(sum(jnp.vdot(x, x)
                                      for x in jax.tree.leaves(g_hat)))
            updates, self.opt_state = self.opt.update(g_hat, self.opt_state,
                                                      self.params)
            self.params = tele.block(optim.apply_updates(self.params,
                                                         updates))

        sel = np.asarray(delta) > 0.5
        mislabeled = (np.asarray(labels) != true)
        frac_bad = (float(np.sum(sel & mislabeled)) / max(np.sum(sel), 1))
        acc = None
        if eval_now:
            with tele.stage("eval"):
                acc = tele.block(self.model.accuracy(
                    self.params, self.data.test_images,
                    self.data.test_labels))
        self._cum = getattr(self, "_cum", 0.0) + dec.net_cost
        n_uploaded = int(np.sum(np.asarray(uploaded)))
        reg = metrics_mod.get_default()
        wall_s = time.perf_counter() - t_round
        if tele.enabled or reg.enabled:
            e_cmp, e_com = self._energy_terms(dec)
            if tele.enabled:
                self._record_round(tele, dec, sel, mislabeled,
                                   np.asarray(uploaded), acc, wall_s,
                                   e_cmp, e_com)
            if reg.enabled:
                self._record_metrics(reg, dec, e_cmp, e_com,
                                     int(np.sum(sel)), n_uploaded, wall_s)
            if tele.enabled and reg.enabled:
                tele.emit(reg.snapshot_event(round=i))
        if self.monitor is not None:
            stage_s = None
            if tele.enabled:
                stage_s = {e.stage: e.dur_s for e in tele.events[ev0:]
                           if isinstance(e, obs.StageEvent)}
            self.monitor.observe_round(
                i, gap=gap_proxy, g_norm_sq=g_norm_sq, eta=cfg.lr,
                delta_obj=float(dec.delta_obj), wall_s=wall_s,
                stage_s=stage_s)
        return RoundMetrics(round=i, net_cost=dec.net_cost,
                            cum_net_cost=self._cum,
                            delta_obj=dec.delta_obj,
                            n_selected=int(np.sum(sel)),
                            n_uploaded=n_uploaded,
                            frac_mislabeled_selected=frac_bad, test_acc=acc)

    def _profile_once(self, name: str, stage: str, fn, args, tele,
                      round_i: int) -> None:
        """Record one roofline ``ProfileEvent`` per (kernel, shapes)."""
        shapes = tuple(tuple(getattr(x, "shape", ()))
                       for x in jax.tree.leaves(args))
        key = (name, shapes)
        if key in self._profiled:
            return
        self._profiled.add(key)
        obs.profile_jitted(fn, args, name=name, stage=stage,
                           telemetry=tele, round=round_i)

    def _energy_terms(self, dec):
        """Per-device E^cmp (eq. 9) and E^com (eq. 16) for the chosen
        decision, as float64 numpy arrays."""
        rho_j = jnp.asarray(dec.rho, jnp.float32)
        p_j = jnp.asarray(dec.p, jnp.float32)
        e_cmp = np.asarray(cost_mod.energy_compute(self.sys), np.float64)
        e_com = np.asarray(cost_mod.energy_upload(self.sys, rho_j, p_j),
                           np.float64)
        return e_cmp, e_com

    def _record_round(self, tele, dec, sel: np.ndarray,
                      mislabeled: np.ndarray, uploaded: np.ndarray,
                      acc, wall_s: float, e_cmp: np.ndarray,
                      e_com: np.ndarray) -> None:
        """Emit the per-device (eqs. 16-18 terms) and round roll-up
        telemetry events.  Only called when the sink is enabled."""
        sys = self.sys
        c = np.asarray(sys.c, np.float64)
        q = np.asarray(sys.q, np.float64)
        m_k = sel.sum(axis=1)
        bad_k = (sel & mislabeled).sum(axis=1) / np.maximum(m_k, 1)
        tele.devices(
            energy_cmp_j=e_cmp.tolist(),
            energy_com_j=e_com.tolist(),
            cost=(c * (e_cmp + e_com)).tolist(),
            reward=(q * m_k).tolist(),
            selected=[int(v) for v in m_k],
            uploaded=[int(v) for v in uploaded],
            mislabel_frac=bad_k.tolist())
        tele.round_end(wall_s=wall_s, net_cost=float(dec.net_cost),
                       delta_obj=float(dec.delta_obj),
                       n_selected=int(sel.sum()),
                       n_uploaded=int(uploaded.sum()),
                       feasible=bool(dec.feasible),
                       test_acc=None if acc is None else float(acc))

    def _record_metrics(self, reg, dec, e_cmp: np.ndarray,
                        e_com: np.ndarray, n_selected: int,
                        n_uploaded: int, wall_s: float) -> None:
        """Per-round budget/outcome metrics (eqs. 16-18).  Only called
        when a real registry is installed."""
        reg.counter("feel_rounds_total", "completed FEEL rounds").inc()
        if not dec.feasible:
            reg.counter("feel_rounds_infeasible_total",
                        "rounds whose decision was infeasible").inc()
        reg.histogram("feel_round_wall_seconds",
                      "wall-clock per FEEL round").observe(wall_s)
        reg.counter("feel_energy_compute_joules_total",
                    "E^cmp (eq. 9) summed over devices and rounds").inc(
                        float(e_cmp.sum()))
        reg.counter("feel_energy_upload_joules_total",
                    "E^com (eq. 16) summed over devices and rounds").inc(
                        float(e_com.sum()))
        reg.counter("feel_samples_selected_total",
                    "samples selected for training").inc(n_selected)
        reg.counter("feel_samples_uploaded_total",
                    "device uploads aggregated").inc(n_uploaded)
        reg.gauge("feel_cum_net_cost",
                  "cumulative net cost (eq. 18) so far").set(self._cum)
        reg.gauge("feel_time_budget_seconds",
                  "per-round upload latency budget T (eq. 16)").set(
                      float(self.sys.T))

    def run(self, rounds: int, verbose: bool = False) -> List[RoundMetrics]:
        out = []
        for i in range(rounds):
            eval_now = (i % self.cfg.eval_every == 0) or i == rounds - 1
            m = self.run_round(i, eval_now=eval_now)
            out.append(m)
            if verbose and eval_now:
                print(f"round {i:4d} acc={m.test_acc} "
                      f"cum_cost={m.cum_net_cost:.4f} sel={m.n_selected} "
                      f"bad_frac={m.frac_mislabeled_selected:.3f}")
        return out
