"""The FEEL communication-round loop (paper §II + Algorithm 1).

Each round:
  1. every device samples |D̂_k| local samples and scores them
     (sigma_{k,j} = per-sample gradient-norm^2);
  2. channels h_{k,n} and availability alpha_k are drawn;
  3. the server runs Algorithm 1 (or a baseline scheme) to fix
     (rho*, p*, delta*) and is billed the net cost (eq. 18);
  4. devices compute local gradients on their *selected* samples
     (eq. 4) — FedSGD; with ``local_steps > 1`` the FedAvg variant of
     footnote 4 runs multiple local steps and uploads model deltas;
  5. the server aggregates with inverse-propensity weights (eq. 19)
     and applies the optimizer update (eq. 20; Adam in §VI-A).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Callable, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .. import checkpoint as ckpt_mod
from .. import obs, optim
from ..obs import metrics as metrics_mod
from ..core import cost as cost_mod
from ..core import joint as joint_mod
from ..core.types import RoundState, SystemParams
from ..data.federated import FederatedDataset
from . import client as client_mod
from . import faults as faults_mod
from . import server as server_mod

Array = jax.Array

#: checkpoint file prefix inside a checkpoint directory.
CKPT_NAME = "feel_ckpt"


@dataclasses.dataclass
class FEELConfig:
    scheme: str = "proposed"          # proposed | baseline1..baseline4
    selection_method: str = "faithful"  # faithful (Alg 4+5) | exact
    # last_layer | last_layer_kernel (one fused all-device pass through
    # kernels/gradnorm) | full
    sigma_method: str = "last_layer"
    power_evaluator: str = "closed_form"  # closed_form | ccp
    # swap-matching sweep: auto (batched at >= AUTO_BATCH_MIN available
    # devices) | scalar | batched — see docs/solvers.md
    matching_mode: str = "auto"
    # 0 = full-matrix Alg. 4; >0 = lax.map over device blocks that size
    selection_chunk: int = 0
    optimizer: str = "adam"
    lr: float = 1e-3
    d_hat: int = 200
    local_steps: int = 1              # >1 => FedAvg variant
    gp_steps: int = 400
    gp_step0: float = 0.3
    warmup_rounds: int = 0    # select ALL samples first (beyond-paper fix:
                              # sigma is uninformative before the model fits)
    eval_every: int = 10
    seed: int = 0


@dataclasses.dataclass
class ResilienceConfig:
    """Knobs of the fault-tolerance layer (docs/robustness.md).

    Passing one to ``FEELTrainer`` (or passing a ``FaultPlan``) turns
    the resilience policies on; with the defaults and no materialized
    fault every round stays bit-for-bit identical to a plain run.
    """

    #: upload deadline in seconds; None derives 1.5 x the slowest
    #: clean completion max_k(tau_k) + T (eqs. 8 + 16 latency model).
    deadline_s: Optional[float] = None
    #: bounded retries for a straggling upload before it is dropped.
    max_retries: int = 2
    #: exponential backoff: retry t waits until deadline * base**t.
    backoff_base: float = 2.0
    #: mid-round dropout handling: "reweight" renormalizes the IPW
    #: aggregation over survivors; "resolve" additionally re-solves the
    #: RB assignment for the survivor set (cost accounting follows).
    dropout_policy: str = "reweight"
    #: consecutive non-finite uploads before a device is quarantined.
    quarantine_threshold: int = 2
    #: rounds a quarantined device sits out; each clean upload
    #: afterwards decays one strike (skip-with-decay).
    quarantine_rounds: int = 3
    #: checkpoint every N rounds (0 = never) into checkpoint_dir.
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None


@dataclasses.dataclass
class RoundMetrics:
    round: int
    net_cost: float
    cum_net_cost: float
    delta_obj: float
    n_selected: int
    n_uploaded: int
    frac_mislabeled_selected: float
    test_acc: Optional[float] = None
    n_dropped: int = 0          # scheduled uploads lost this round
    n_quarantined: int = 0      # devices sitting out this round
    n_retries: int = 0          # straggler retry attempts this round
    skipped_update: bool = False  # no usable upload -> no optimizer step
    fallbacks: tuple = ()       # solver degradations (RoundDecision)


class FEELTrainer:
    """Drives FEEL rounds for an image-classification model."""

    def __init__(self, sys: SystemParams, data: FederatedDataset,
                 model, params, cfg: FEELConfig,
                 telemetry: Optional[obs.NullTelemetry] = None,
                 monitor: Optional["obs.ConvergenceMonitor"] = None,
                 faults: Optional[Union["faults_mod.FaultPlan",
                                        "faults_mod.FaultSpec"]] = None,
                 resilience: Optional[ResilienceConfig] = None):
        """``model`` exposes features(params, x), apply, loss_fn, accuracy.

        ``telemetry``: an ``obs`` sink for the round-level trace; the
        default (``None``) resolves to the process-wide sink, which is
        a no-op unless e.g. ``benchmarks/run.py --trace`` installed one.

        ``monitor``: an ``obs.ConvergenceMonitor`` fed one observation
        per round (training-loss gap proxy, ||g_hat||^2, step size, the
        decision's Delta term, wall/stage timings).  ``None`` (default)
        skips every monitor code path — round outputs stay bit-for-bit
        identical.  Metrics flow to the process-default registry
        (``obs.metrics.set_default``), also a no-op unless installed.

        ``faults``: a ``repro.fed.faults.FaultPlan`` (or its spec)
        injecting post-matching dropout, straggler delays, NaN uploads
        and forced solver failures — deterministic and replayable.

        ``resilience``: a ``ResilienceConfig`` with the policy knobs
        (deadline/retry/backoff, dropout policy, quarantine,
        checkpointing).  Either argument activates the resilience
        layer; ``None``+``None`` (default) keeps every round bit-for-
        bit identical to the pre-fault-tolerance trainer.
        """
        self.sys = sys
        self.data = data
        self.model = model
        self.params = params
        self.cfg = cfg
        self.obs = obs.resolve(telemetry)
        self.monitor = monitor
        if isinstance(faults, faults_mod.FaultSpec):
            faults = faults_mod.FaultPlan(faults)
        self.faults = faults
        self.resilience = resilience
        self._resilient = faults is not None or resilience is not None
        self._res = resilience if resilience is not None \
            else ResilienceConfig()
        self._strikes = np.zeros(sys.K, np.int64)
        self._quarantined_until = np.zeros(sys.K, np.int64)
        self._start_round = 0
        self._cum = 0.0
        self._profiled: set = set()
        self.rng = np.random.default_rng(cfg.seed)
        self.key = jax.random.PRNGKey(cfg.seed)
        opt_builder = {"adam": optim.adam, "sgd": optim.sgd,
                       "momentum": optim.momentum,
                       "adafactor": optim.adafactor}[cfg.optimizer]
        self.opt = opt_builder(cfg.lr)
        self.opt_state = self.opt.init(params)
        self._build_jitted()

    # ------------------------------------------------------------------
    def _build_jitted(self):
        model, cfg = self.model, self.cfg

        if cfg.sigma_method == "last_layer_kernel":
            @jax.jit
            def sigma_all(params, images, labels):
                """(K, D̂) sigma via one fused all-device kernel pass."""
                return client_mod.batched_sigma(params, images, labels,
                                                features_fn=model.features)
        else:
            @jax.jit
            def sigma_all(params, images, labels):
                """(K, D̂) sigma scores."""
                f = functools.partial(client_mod.per_sample_sigma,
                                      features_fn=model.features,
                                      method=cfg.sigma_method,
                                      loss_fn=model.loss_fn)
                return jax.vmap(lambda im, lb: f(params, im, lb))(images,
                                                                  labels)

        @jax.jit
        def local_grads(params, images, labels, delta):
            """pytree with leading K axis (FedSGD local gradients)."""
            return jax.vmap(
                lambda im, lb, dl: client_mod.local_gradient(
                    params, im, lb, dl, model.loss_fn))(images, labels,
                                                        delta)

        @jax.jit
        def local_deltas(params, images, labels, delta, lr):
            """FedAvg: run local_steps SGD steps, return param deltas."""

            def one_device(im, lb, dl):
                def step(p, _):
                    g = client_mod.local_gradient(p, im, lb, dl,
                                                  model.loss_fn)
                    p = jax.tree.map(lambda a, b: a - lr * b, p, g)
                    return p, None

                p_out, _ = jax.lax.scan(step, params, None,
                                        length=cfg.local_steps)
                # pseudo-gradient: (w - w_k') / lr, aggregated like a grad
                return jax.tree.map(lambda a, b: (a - b) / lr, params, p_out)

            return jax.vmap(one_device)(images, labels, delta)

        if self.obs.annotate:
            # optional jax.profiler trace annotations: the jitted round
            # computations show up named in TensorBoard/Perfetto traces
            sigma_all = obs.annotate_fn(sigma_all, "repro.sigma_all")
            local_grads = obs.annotate_fn(local_grads, "repro.local_grads")
            local_deltas = obs.annotate_fn(local_deltas,
                                           "repro.local_deltas")

        self._sigma_all = sigma_all
        self._local_grads = local_grads
        self._local_deltas = local_deltas

    # ------------------------------------------------------------------
    def _gather_round_batches(self):
        idx = self.data.sample_subsets(self.rng, self.cfg.d_hat)
        imgs = np.stack([self.data.device_images[k][idx[k]]
                         for k in range(self.sys.K)])
        labels = np.stack([self.data.device_labels[k][idx[k]]
                           for k in range(self.sys.K)])
        true = np.stack([self.data.device_true[k][idx[k]]
                         for k in range(self.sys.K)])
        return jnp.asarray(imgs), jnp.asarray(labels), true


    def run_round(self, i: int, eval_now: bool = False) -> RoundMetrics:
        sys, cfg, tele = self.sys, self.cfg, self.obs
        t_round = time.perf_counter()
        tele.begin_round(i)
        ev0 = len(tele.events) if tele.enabled else 0
        # root of this round's span tree (schema v4): every stage/span
        # opened below records it as parent, so export/diff/dash can
        # reconstruct the full call hierarchy.  Entered manually — the
        # span must close just before RoundMetrics is built so eval and
        # aggregation land inside it.
        span_round = tele.span("round")
        span_round.__enter__()
        rf = (self.faults.for_round(i, sys.K)
              if self.faults is not None else None)

        with tele.stage("data"):
            images, labels, true = self._gather_round_batches()
        self.key, kh, ka, kb = jax.random.split(self.key, 4)

        if tele.profile:
            self._profile_once("sigma_all", "sigma", self._sigma_all,
                               (self.params, images, labels), tele, i)
        with tele.stage("sigma"):
            sigma = tele.block(self._sigma_all(self.params, images, labels))
        h = jax.random.exponential(kh, (sys.K, sys.N)) * 1e-5
        alpha = (jax.random.uniform(ka, (sys.K,)) < sys.eps
                 ).astype(jnp.float32)
        n_quarantined = 0
        if self._resilient:
            # quarantined devices sit the round out *before* the solve,
            # so no RB/power is allocated to them (skip-with-decay)
            quarantined = self._quarantined_until > i
            n_quarantined = int(np.sum(quarantined))
            if n_quarantined:
                alpha = alpha * jnp.asarray(~quarantined, jnp.float32)
        mask = jnp.ones_like(sigma)
        state = RoundState(h=h, alpha=alpha, sigma=sigma, sigma_mask=mask)

        if cfg.scheme == "proposed" and i < cfg.warmup_rounds:
            # warmup: resource allocation as proposed, selection = all
            match = joint_mod.matching_mod.swap_matching(
                sys, state.h, state.alpha,
                evaluator=cfg.power_evaluator,
                mode=(cfg.matching_mode
                      if cfg.power_evaluator == "closed_form" else "auto"),
                telemetry=tele)
            with tele.stage("selection"):
                pass  # warmup selects everything; keep the stage present
            dec = joint_mod._finish(sys, match.rho, match.p,
                                    np.asarray(mask), state,
                                    feasible=match.feasible,
                                    swaps=match.swaps,
                                    unmatched=match.unmatched,
                                    telemetry=tele)
        elif cfg.scheme == "proposed":
            dec = joint_mod.proposed_scheme(
                sys, state, selection_method=cfg.selection_method,
                power_evaluator=cfg.power_evaluator, gp_steps=cfg.gp_steps,
                gp_step0=cfg.gp_step0, matching_mode=cfg.matching_mode,
                selection_chunk=cfg.selection_chunk, faults=rf,
                repair_infeasible=self._resilient, telemetry=tele)
        elif cfg.scheme.startswith("baseline"):
            dec = joint_mod.baseline_scheme(sys, state,
                                            int(cfg.scheme[-1]), key=kb,
                                            telemetry=tele)
        else:
            raise ValueError(cfg.scheme)

        delta = jnp.asarray(dec.delta)
        matched = jnp.asarray(dec.rho.sum(axis=1) > 0, jnp.float32)
        uploaded = alpha * matched

        gap_proxy = None
        if self.monitor is not None:
            # mean training loss on the round batch under the PRE-update
            # params: the Lemma-2 gap proxy (L* offset cancels, see
            # repro.obs.monitor).  Read-only — numerics are untouched.
            flat_im = images.reshape((-1,) + images.shape[2:])
            gap_proxy = float(self.model.loss_fn(self.params, flat_im,
                                                 labels.reshape(-1)))

        if tele.profile:
            if cfg.local_steps > 1:
                self._profile_once(
                    "local_deltas", "local_grads", self._local_deltas,
                    (self.params, images, labels, delta,
                     jnp.asarray(cfg.lr)), tele, i)
            else:
                self._profile_once(
                    "local_grads", "local_grads", self._local_grads,
                    (self.params, images, labels, delta), tele, i)
        with tele.stage("local_grads"):
            if cfg.local_steps > 1:
                grads = self._local_deltas(self.params, images, labels,
                                           delta, jnp.asarray(cfg.lr))
            else:
                grads = self._local_grads(self.params, images, labels,
                                          delta)
            grads = tele.block(grads)

        # ---- fault application + resilience policies ------------------
        planned = np.asarray(uploaded) > 0
        surv = planned
        n_dropped = n_retries = 0
        if self._resilient:
            surv, n_dropped, n_retries = self._upload_outcomes(
                i, rf, planned, tele)
            grads = self._inject_nan_uploads(rf, surv, grads, tele)
            surv, n_bad = self._screen_nonfinite(i, rf, surv, grads, tele)
            n_dropped += n_bad

        g_norm_sq = None
        skipped_update = False
        with tele.stage("aggregate"):
            if self._resilient and not np.array_equal(surv, planned):
                surv_j = jnp.asarray(surv, jnp.float32)
                if self._res.dropout_policy == "resolve" and surv.any():
                    dec = self._resolve_for_survivors(state, surv_j, dec,
                                                      tele)
                # zero the lost uploads before the weighted sum: their
                # IPW weight is 0, but 0 * NaN would still poison it
                surv_b = jnp.asarray(surv)

                def scrub(leaf):
                    shape = (sys.K,) + (1,) * (leaf.ndim - 1)
                    return jnp.where(surv_b.reshape(shape), leaf, 0.0)

                grads = jax.tree.map(scrub, grads)
                # IPW-consistent reweighting over the survivor set
                g_hat = server_mod.aggregate_gradients(sys, grads, surv_j,
                                                       renormalize=True)
                mass = server_mod.ipw_mass(sys, surv_j)
            else:
                # clean round: the exact pre-fault-tolerance aggregation
                g_hat = server_mod.aggregate_gradients(sys, grads,
                                                       uploaded)
                mass = server_mod.ipw_mass(sys, uploaded)
            if mass <= 0.0:
                # every upload was lost (or none was scheduled): applying
                # the zero/NaN step would still move Adam's state, so the
                # update is skipped and recorded instead
                skipped_update = True
                g_norm_sq = 0.0 if self.monitor is not None else None
                tele.fault("skip_update", injected=False,
                           reason="no_surviving_upload")
                reg0 = metrics_mod.get_default()
                if reg0.enabled:
                    reg0.counter("feel_rounds_skipped_total",
                                 "rounds whose optimizer update was "
                                 "skipped (no usable upload)").inc()
            else:
                if self.monitor is not None:
                    g_norm_sq = float(sum(jnp.vdot(x, x)
                                          for x in jax.tree.leaves(g_hat)))
                updates, self.opt_state = self.opt.update(
                    g_hat, self.opt_state, self.params)
                self.params = tele.block(optim.apply_updates(self.params,
                                                             updates))

        sel = np.asarray(delta) > 0.5
        mislabeled = (np.asarray(labels) != true)
        frac_bad = (float(np.sum(sel & mislabeled)) / max(np.sum(sel), 1))
        acc = None
        if eval_now:
            with tele.stage("eval"):
                acc = tele.block(self.model.accuracy(
                    self.params, self.data.test_images,
                    self.data.test_labels))
        self._cum = self._cum + dec.net_cost
        n_uploaded = int(np.sum(surv))
        reg = metrics_mod.get_default()
        wall_s = time.perf_counter() - t_round
        if tele.enabled or reg.enabled:
            e_cmp, e_com = self._energy_terms(dec)
            if tele.enabled:
                self._record_round(tele, dec, sel, mislabeled,
                                   surv.astype(np.int64), acc, wall_s,
                                   e_cmp, e_com)
            if reg.enabled:
                self._record_metrics(reg, dec, e_cmp, e_com,
                                     int(np.sum(sel)), n_uploaded, wall_s)
            if tele.enabled and reg.enabled:
                tele.emit(reg.snapshot_event(round=i))
        if self.monitor is not None:
            stage_s = None
            if tele.enabled:
                stage_s = {e.stage: e.dur_s for e in tele.events[ev0:]
                           if isinstance(e, obs.StageEvent)}
            self.monitor.observe_round(
                i, gap=gap_proxy, g_norm_sq=g_norm_sq, eta=cfg.lr,
                delta_obj=float(dec.delta_obj), wall_s=wall_s,
                stage_s=stage_s)
        if (self._res.checkpoint_every > 0 and self._res.checkpoint_dir
                and (i + 1) % self._res.checkpoint_every == 0):
            path = self.save_checkpoint(next_round=i + 1)
            tele.fault("checkpoint", injected=False, path=path,
                       next_round=i + 1)
            if reg.enabled:
                reg.counter("feel_checkpoints_total",
                            "periodic trainer checkpoints written").inc()
        span_round.__exit__(None, None, None)
        return RoundMetrics(round=i, net_cost=dec.net_cost,
                            cum_net_cost=self._cum,
                            delta_obj=dec.delta_obj,
                            n_selected=int(np.sum(sel)),
                            n_uploaded=n_uploaded,
                            frac_mislabeled_selected=frac_bad,
                            test_acc=acc, n_dropped=n_dropped,
                            n_quarantined=n_quarantined,
                            n_retries=n_retries,
                            skipped_update=skipped_update,
                            fallbacks=dec.fallbacks)

    def _profile_once(self, name: str, stage: str, fn, args, tele,
                      round_i: int) -> None:
        """Record one roofline ``ProfileEvent`` per (kernel, shapes)."""
        shapes = tuple(tuple(getattr(x, "shape", ()))
                       for x in jax.tree.leaves(args))
        key = (name, shapes)
        if key in self._profiled:
            return
        self._profiled.add(key)
        obs.profile_jitted(fn, args, name=name, stage=stage,
                           telemetry=tele, round=round_i)

    def _energy_terms(self, dec):
        """Per-device E^cmp (eq. 9) and E^com (eq. 16) for the chosen
        decision, as float64 numpy arrays."""
        rho_j = jnp.asarray(dec.rho, jnp.float32)
        p_j = jnp.asarray(dec.p, jnp.float32)
        e_cmp = np.asarray(cost_mod.energy_compute(self.sys), np.float64)
        e_com = np.asarray(cost_mod.energy_upload(self.sys, rho_j, p_j),
                           np.float64)
        return e_cmp, e_com

    def _record_round(self, tele, dec, sel: np.ndarray,
                      mislabeled: np.ndarray, uploaded: np.ndarray,
                      acc, wall_s: float, e_cmp: np.ndarray,
                      e_com: np.ndarray) -> None:
        """Emit the per-device (eqs. 16-18 terms) and round roll-up
        telemetry events.  Only called when the sink is enabled."""
        sys = self.sys
        c = np.asarray(sys.c, np.float64)
        q = np.asarray(sys.q, np.float64)
        m_k = sel.sum(axis=1)
        bad_k = (sel & mislabeled).sum(axis=1) / np.maximum(m_k, 1)
        tele.devices(
            energy_cmp_j=e_cmp.tolist(),
            energy_com_j=e_com.tolist(),
            cost=(c * (e_cmp + e_com)).tolist(),
            reward=(q * m_k).tolist(),
            selected=[int(v) for v in m_k],
            uploaded=[int(v) for v in uploaded],
            mislabel_frac=bad_k.tolist())
        tele.round_end(wall_s=wall_s, net_cost=float(dec.net_cost),
                       delta_obj=float(dec.delta_obj),
                       n_selected=int(sel.sum()),
                       n_uploaded=int(uploaded.sum()),
                       feasible=bool(dec.feasible),
                       test_acc=None if acc is None else float(acc))

    def _record_metrics(self, reg, dec, e_cmp: np.ndarray,
                        e_com: np.ndarray, n_selected: int,
                        n_uploaded: int, wall_s: float) -> None:
        """Per-round budget/outcome metrics (eqs. 16-18).  Only called
        when a real registry is installed."""
        reg.counter("feel_rounds_total", "completed FEEL rounds").inc()
        if not dec.feasible:
            reg.counter("feel_rounds_infeasible_total",
                        "rounds whose decision was infeasible").inc()
        reg.histogram("feel_round_wall_seconds",
                      "wall-clock per FEEL round").observe(wall_s)
        reg.counter("feel_energy_compute_joules_total",
                    "E^cmp (eq. 9) summed over devices and rounds").inc(
                        float(e_cmp.sum()))
        reg.counter("feel_energy_upload_joules_total",
                    "E^com (eq. 16) summed over devices and rounds").inc(
                        float(e_com.sum()))
        reg.counter("feel_samples_selected_total",
                    "samples selected for training").inc(n_selected)
        reg.counter("feel_samples_uploaded_total",
                    "device uploads aggregated").inc(n_uploaded)
        reg.gauge("feel_cum_net_cost",
                  "cumulative net cost (eq. 18) so far").set(self._cum)
        reg.gauge("feel_time_budget_seconds",
                  "per-round upload latency budget T (eq. 16)").set(
                      float(self.sys.T))

    # ------------------------------------------------------------------
    # fault-tolerance layer (docs/robustness.md)
    # ------------------------------------------------------------------
    @staticmethod
    def _count_injected(kind: str, n: int = 1) -> None:
        reg = metrics_mod.get_default()
        if reg.enabled and n:
            reg.counter("feel_faults_injected_total",
                        "faults injected by the FaultPlan, by kind").inc(
                            n, kind=kind)

    def _upload_outcomes(self, i: int, rf, planned: np.ndarray, tele):
        """Apply post-matching dropout and the straggler deadline with
        bounded retry + exponential backoff.  Returns the surviving
        upload mask plus (dropped, retry) counts."""
        res = self._res
        surv = planned.copy()
        n_dropped = n_retries = 0
        if rf is not None and rf.dropout.any():
            lost = planned & rf.dropout
            for k in np.flatnonzero(lost):
                tele.fault("dropout", injected=True, device=int(k))
            self._count_injected("dropout", int(lost.sum()))
            surv &= ~lost
            n_dropped += int(lost.sum())
        # upload completion per the eq. (8)+(16) latency model: compute
        # time tau_k plus the T-second upload slot, plus injected delay
        tau = np.asarray(cost_mod.compute_time(self.sys), np.float64)
        T = float(self.sys.T)
        deadline = (res.deadline_s if res.deadline_s is not None
                    else 1.5 * float(tau.max() + T))
        delays = rf.delay_s if rf is not None else np.zeros(self.sys.K)
        for k in np.flatnonzero(surv):
            # one span per attempted upload: carries the device index so
            # the Perfetto export lands it on that device's own track
            with tele.span("device.upload", device=int(k),
                           tau_s=float(tau[k])):
                if tau[k] + T + float(delays[k]) <= deadline:
                    continue
                injected = bool(rf is not None and rf.straggler[k])
                ok = False
                for t in range(1, res.max_retries + 1):
                    n_retries += 1
                    window = deadline * res.backoff_base ** t
                    d_t = (self.faults.retry_delay_s(i, int(k), t)
                           if self.faults is not None else 0.0)
                    tele.fault("retry", injected=injected, device=int(k),
                               attempt=t, delay_s=d_t, window_s=window)
                    if tau[k] + T + d_t <= window:
                        ok = True
                        break
                tele.fault("straggler", injected=injected, device=int(k),
                           delay_s=float(delays[k]), dropped=not ok,
                           retries=n_retries)
                if injected:
                    self._count_injected("straggler")
                if not ok:
                    surv[k] = False
                    n_dropped += 1
        reg = metrics_mod.get_default()
        if reg.enabled:
            if n_retries:
                reg.counter("feel_retries_total",
                            "straggler upload retry attempts").inc(
                                n_retries)
            if n_dropped:
                reg.counter("feel_dropouts_total",
                            "scheduled uploads lost mid-round").inc(
                                n_dropped)
        return surv, n_dropped, n_retries

    def _inject_nan_uploads(self, rf, surv: np.ndarray, grads, tele):
        """Corrupt the gradient upload of fault-plan-selected devices
        with NaNs (the defense then has to catch real NaNs)."""
        if rf is None or not bool((rf.nan_upload & surv).any()):
            return grads
        bad = rf.nan_upload & surv
        self._count_injected("nan_upload", int(bad.sum()))
        bad_j = jnp.asarray(bad)

        def corrupt(leaf):
            shape = (self.sys.K,) + (1,) * (leaf.ndim - 1)
            return jnp.where(bad_j.reshape(shape), jnp.nan, leaf)

        return jax.tree.map(corrupt, grads)

    def _screen_nonfinite(self, i: int, rf, surv: np.ndarray, grads,
                          tele):
        """Exclude non-finite uploads from aggregation and run the
        per-device quarantine (skip-with-decay) bookkeeping."""
        K = self.sys.K
        finite = np.ones(K, bool)
        for leaf in jax.tree.leaves(grads):
            ax = tuple(range(1, leaf.ndim))
            finite &= np.asarray(jnp.all(jnp.isfinite(leaf), axis=ax))
        bad = surv & ~finite
        clean = surv & finite
        res = self._res
        reg = metrics_mod.get_default()
        if bad.any() and reg.enabled:
            reg.counter("feel_nan_uploads_total",
                        "uploads excluded for non-finite values").inc(
                            int(bad.sum()))
        for k in np.flatnonzero(bad):
            self._strikes[k] += 1
            injected = bool(rf is not None and rf.nan_upload[k])
            tele.fault("nan_upload", injected=injected, device=int(k),
                       strikes=int(self._strikes[k]))
            if self._strikes[k] >= res.quarantine_threshold:
                until = i + 1 + res.quarantine_rounds
                self._quarantined_until[k] = until
                self._strikes[k] = 0
                tele.fault("quarantine", injected=False, device=int(k),
                           until_round=int(until))
                if reg.enabled:
                    reg.counter("feel_quarantines_total",
                                "devices quarantined for repeated "
                                "non-finite uploads").inc()
        # each clean upload decays one strike
        self._strikes[clean] = np.maximum(self._strikes[clean] - 1, 0)
        return surv & finite, int(bad.sum())

    def _resolve_for_survivors(self, state, surv_j, dec, tele):
        """Dropout policy "resolve": cheaply re-solve the RB assignment
        for the surviving devices so energy/cost accounting matches who
        actually uploaded.  Falls back to keeping the original decision
        (reweight-only) if the re-solve itself fails."""
        sys = self.sys
        try:
            match2 = joint_mod.matching_mod.swap_matching(
                sys, state.h, surv_j, evaluator="closed_form",
                mode=self.cfg.matching_mode, telemetry=tele)
        except Exception as e:  # keep the round alive
            tele.fault("solver_fail", injected=False, solver="matching",
                       reason=type(e).__name__, context="resolve")
            return dec
        tele.fault("fallback", injected=False, solver="matching",
                   to="resolve_survivors")
        reg = metrics_mod.get_default()
        if reg.enabled:
            reg.counter("feel_fallbacks_total",
                        "solver degradations by solver and target").inc(
                            1, solver="matching", to="resolve_survivors")
        return joint_mod._finish(
            sys, match2.rho, match2.p, dec.delta, state,
            feasible=match2.feasible, swaps=dec.swaps,
            unmatched=match2.unmatched,
            fallbacks=dec.fallbacks + ("resolve_survivors",),
            telemetry=tele)

    # ------------------------------------------------------------------
    # crash-safe checkpoint / resume (docs/robustness.md)
    # ------------------------------------------------------------------
    def save_checkpoint(self, path: Optional[str] = None,
                        next_round: int = 0) -> str:
        """Atomically persist everything ``resume`` needs to reproduce
        the uninterrupted trajectory bit-for-bit: params, optimizer
        state, both RNG streams, the round index, cumulative cost and
        the quarantine bookkeeping."""
        if path is None:
            if not self._res.checkpoint_dir:
                raise ValueError("no checkpoint path: pass one or set "
                                 "ResilienceConfig.checkpoint_dir")
            path = os.path.join(self._res.checkpoint_dir, CKPT_NAME)
        meta = {
            "next_round": int(next_round),
            "cum_net_cost": float(self._cum),
            "rng_state": self.rng.bit_generator.state,
            "jax_key": np.asarray(self.key).tolist(),
            "strikes": [int(v) for v in self._strikes],
            "quarantined_until": [int(v) for v in self._quarantined_until],
            "seed": int(self.cfg.seed),
            "fault_spec": (self.faults.to_dict()
                           if self.faults is not None else None),
        }
        ckpt_mod.save_pytree(path, {"params": self.params,
                                    "opt_state": self.opt_state},
                             metadata=meta)
        return path

    def resume(self, path: Optional[str] = None) -> int:
        """Restore a ``save_checkpoint`` state and return the round to
        continue from (``run`` picks it up automatically).  Because the
        fault plan, both RNG streams and the quarantine state are all
        restored, the resumed trajectory is bit-identical to the
        uninterrupted one."""
        if path is None:
            if not self._res.checkpoint_dir:
                raise ValueError("no checkpoint path: pass one or set "
                                 "ResilienceConfig.checkpoint_dir")
            path = self._res.checkpoint_dir
        if os.path.isdir(path):
            path = os.path.join(path, CKPT_NAME)
        like = {"params": self.params, "opt_state": self.opt_state}
        tree = ckpt_mod.load_pytree(path, like)
        meta = ckpt_mod.load_metadata(path)
        if meta is None:
            raise FileNotFoundError(f"{path}.meta.json missing — cannot "
                                    "resume without trainer metadata")
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self._cum = float(meta["cum_net_cost"])
        rng = np.random.default_rng()
        rng.bit_generator.state = meta["rng_state"]
        self.rng = rng
        self.key = jnp.asarray(np.asarray(meta["jax_key"], np.uint32))
        self._strikes = np.asarray(meta["strikes"], np.int64)
        self._quarantined_until = np.asarray(meta["quarantined_until"],
                                             np.int64)
        self._start_round = int(meta["next_round"])
        self.obs.fault("resume", injected=False, path=path,
                       next_round=self._start_round)
        return self._start_round

    def run(self, rounds: int, verbose: bool = False) -> List[RoundMetrics]:
        """Run rounds ``[start, rounds)`` where ``start`` is 0 for a
        fresh trainer or the restored round index after ``resume()``."""
        out = []
        for i in range(self._start_round, rounds):
            eval_now = (i % self.cfg.eval_every == 0) or i == rounds - 1
            m = self.run_round(i, eval_now=eval_now)
            out.append(m)
            if verbose and eval_now:
                print(f"round {i:4d} acc={m.test_acc} "
                      f"cum_cost={m.cum_net_cost:.4f} sel={m.n_selected} "
                      f"bad_frac={m.frac_mislabeled_selected:.3f}")
        return out
