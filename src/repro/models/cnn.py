"""The paper's CNN (§VI-A): two 5x5 conv layers (10, 20 channels), each
followed by 2x2 max-pooling, then three fully-connected ReLU layers.

Pure-functional: ``init`` builds a params pytree, ``apply`` maps
(params, images) -> logits, ``features`` additionally returns the
penultimate activations (used by the exact last-layer sigma scorer).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    side: int = 28
    num_classes: int = 10
    conv_channels: Tuple[int, int] = (10, 20)
    fc_dims: Tuple[int, int] = (120, 84)

    @property
    def feature_dim(self) -> int:
        s = self.side // 4  # two 2x2 pools
        return s * s * self.conv_channels[1]


def init(key: Array, cfg: CNNConfig) -> dict:
    k = jax.random.split(key, 5)
    c1, c2 = cfg.conv_channels
    f1, f2 = cfg.fc_dims
    he = jax.nn.initializers.he_normal()
    return {
        "conv1": {"w": he(k[0], (5, 5, 1, c1), jnp.float32),
                  "b": jnp.zeros((c1,))},
        "conv2": {"w": he(k[1], (5, 5, c1, c2), jnp.float32),
                  "b": jnp.zeros((c2,))},
        "fc1": {"w": he(k[2], (cfg.feature_dim, f1), jnp.float32),
                "b": jnp.zeros((f1,))},
        "fc2": {"w": he(k[3], (f1, f2), jnp.float32), "b": jnp.zeros((f2,))},
        "out": {"w": he(k[4], (f2, cfg.num_classes), jnp.float32),
                "b": jnp.zeros((cfg.num_classes,))},
    }


def _conv(x: Array, w: Array, b: Array) -> Array:
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _pool(x: Array) -> Array:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def features(params: dict, images: Array) -> Tuple[Array, Array]:
    """(penultimate features h, logits). images: (B, side, side)."""
    x = images[..., None]
    x = _pool(jax.nn.relu(_conv(x, params["conv1"]["w"],
                                params["conv1"]["b"])))
    x = _pool(jax.nn.relu(_conv(x, params["conv2"]["w"],
                                params["conv2"]["b"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    h = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
    logits = h @ params["out"]["w"] + params["out"]["b"]
    return h, logits


def apply(params: dict, images: Array) -> Array:
    return features(params, images)[1]


def loss_fn(params: dict, images: Array, labels: Array) -> Array:
    """Mean cross-entropy."""
    logits = apply(params, images)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(params: dict, images: Array, labels: Array,
             batch: int = 512) -> float:
    correct = 0
    n = images.shape[0]
    for i in range(0, n, batch):
        logits = apply(params, images[i:i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1)
                               == labels[i:i + batch]))
    return correct / n
