"""Mamba-1 selective SSM mixer (Falcon-Mamba-7B architecture).

TPU adaptation (DESIGN.md §2): the recurrence
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
is diagonal per (channel, state), so train/prefill runs as a log-depth
``jax.lax.associative_scan`` over the sequence axis instead of a CUDA
sequential kernel; decode is the single-step recurrence on a carried
(conv_state, ssm_state).  kernels/lru_scan.py provides the Pallas
blocked-scan version of the same contraction.

Cache layout: {"conv": (B, k-1, d_inner), "h": (B, d_inner, n)}.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import init_dense
from .shard_ctx import constrain

Array = jax.Array


def init_mamba(key, cfg: ArchConfig, dtype) -> dict:
    d, di, n = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    dtr, k = cfg.ssm_dt_rank_, cfg.ssm_conv
    ks = jax.random.split(key, 7)
    return {
        "in_proj": init_dense(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (k, di), jnp.float32)
                   * (1.0 / k ** 0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": init_dense(ks[2], di, dtr + 2 * n, dtype),
        "dt_proj": init_dense(ks[3], dtr, di, dtype),
        "dt_bias": (jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1)))))
                    ).astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": init_dense(ks[5], di, d, dtype),
    }


def _ssm_params(cfg: ArchConfig, p: dict, s: Array):
    """dt (B,S,di), Bmat (B,S,n), Cmat (B,S,n) from conv output s."""
    dtr, n = cfg.ssm_dt_rank_, cfg.ssm_state
    xdb = s @ p["x_proj"]
    dt_raw, Bmat, Cmat = jnp.split(xdb, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) @
                         p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    return dt, Bmat.astype(jnp.float32), Cmat.astype(jnp.float32)


def _scan_assoc(dA: Array, dBx: Array) -> Array:
    """Associative scan of h_t = dA_t h_{t-1} + dBx_t along axis 1."""

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    return h


def _causal_conv(p: dict, x: Array, k: int) -> Array:
    """Depthwise causal conv along seq: x (B, S, di)."""
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # depthwise: sum_j w[j, c] * x[t - (k-1) + j, c]
    return sum(pad[:, j:j + x.shape[1], :] * p["conv_w"][j]
               for j in range(k)) + p["conv_b"]


def mamba_mixer(cfg: ArchConfig, p: dict, x: Array, mode: str,
                cache: Optional[dict]) -> Tuple[Array, Optional[dict]]:
    B, S, _ = x.shape
    di, n, k = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_conv
    A = -jnp.exp(p["A_log"])  # (di, n)

    u = x @ p["in_proj"]
    xs, z = jnp.split(u, 2, axis=-1)
    xs = constrain(xs, "act_btf")

    if mode in ("train", "prefill"):
        conv_out = _causal_conv(p, xs, k)
        s = jax.nn.silu(conv_out)
        dt, Bmat, Cmat = _ssm_params(cfg, p, s)
        sf = s.astype(jnp.float32)
        dA = jnp.exp(dt[..., None] * A)                       # (B,S,di,n)
        dBx = dt[..., None] * Bmat[:, :, None, :] * sf[..., None]
        h = _scan_assoc(dA, dBx)                              # (B,S,di,n)
        y = jnp.einsum("bsdn,bsn->bsd", h, Cmat) + p["D"] * sf
        new_cache = None
        if mode == "prefill":
            # last k-1 inputs, zero-left-padded when S < k-1
            xp = jnp.pad(xs, ((0, 0), (max(k - 1 - S, 0), 0), (0, 0)))
            new_cache = {"conv": xp[:, xp.shape[1] - (k - 1):, :],
                         "h": h[:, -1]}  # (B,di,n)
    else:
        assert cache is not None
        conv_buf = jnp.concatenate(
            [cache["conv"], xs.astype(cache["conv"].dtype)], axis=1)
        conv_out = (jnp.einsum("bkd,kd->bd", conv_buf, p["conv_w"])
                    + p["conv_b"])[:, None, :]
        s = jax.nn.silu(conv_out)
        dt, Bmat, Cmat = _ssm_params(cfg, p, s)
        sf = s.astype(jnp.float32)
        dA = jnp.exp(dt[:, 0, :, None] * A)                   # (B,di,n)
        dBx = dt[:, 0, :, None] * Bmat[:, 0, None, :] * sf[:, 0, :, None]
        h1 = dA * cache["h"] + dBx
        y = (jnp.einsum("bdn,bn->bd", h1, Cmat[:, 0])
             + p["D"] * sf[:, 0])[:, None, :]
        new_cache = {"conv": conv_buf[:, 1:, :], "h": h1}

    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return y, new_cache
