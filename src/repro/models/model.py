"""Model-level API: embeddings/heads per modality, losses, and the
train / prefill / decode step functions the launcher jits.

Modalities (DESIGN.md §3):
  text   tokens (B,S) int32 -> embedding table
  vlm    precomputed patch/text embeddings (B,S,d) + M-RoPE position
         ids (B,3,S) — the ViT frontend is the allowed stub
  audio  EnCodec token grid (B, n_codebooks, S) -> summed codebook
         embeddings; n_codebooks parallel LM heads (MusicGen)

The FEEL integration (`make_train_step(..., feel=...)`) implements the
paper's technique inside the jitted step: per-example gradient-norm
scores sigma (exact last-layer row-norm product, kernels/gradnorm),
the exact Problem-4 selector per client, and eq.-(19) inverse-
propensity weighting with Bernoulli availability — the mesh "data"
axis plays the role of the K federated devices.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import selection as sel_mod
from ..core.types import SystemParams
from ..optim import GradientTransformation, apply_updates
from .config import ArchConfig
from .layers import init_dense
from .shard_ctx import constrain
from .transformer import apply_decoder, init_cache, init_decoder

Array = jax.Array


# ---------------------------------------------------------------- params

def init_model(key, cfg: ArchConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dtype = cfg.act_dtype
    params: dict = {"decoder": init_decoder(k1, cfg)}
    if cfg.modality == "text":
        params["embed"] = (jax.random.normal(
            k2, (cfg.vocab, cfg.d_model), jnp.float32)
            * cfg.d_model ** -0.5).astype(dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = init_dense(k3, cfg.d_model, cfg.vocab, dtype)
    elif cfg.modality == "vlm":
        params["lm_head"] = init_dense(k3, cfg.d_model, cfg.vocab, dtype)
    elif cfg.modality == "audio":
        params["embed"] = (jax.random.normal(
            k2, (cfg.n_codebooks, cfg.vocab, cfg.d_model), jnp.float32)
            * cfg.d_model ** -0.5).astype(dtype)
        params["lm_head"] = init_dense(k3, cfg.d_model,
                                       cfg.n_codebooks * cfg.vocab, dtype)
    else:
        raise ValueError(cfg.modality)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ------------------------------------------------------------ embeddings

def embed_input(cfg: ArchConfig, params: dict, batch: Dict[str, Array]
                ) -> Array:
    if cfg.modality == "text":
        return jnp.take(params["embed"], batch["tokens"], axis=0
                        ).astype(cfg.act_dtype)
    if cfg.modality == "vlm":
        return batch["embeds"].astype(cfg.act_dtype)
    if cfg.modality == "audio":
        # sum of per-codebook embeddings: tokens (B, C, S)
        toks = batch["tokens"]
        embs = jnp.take(params["embed"][0], toks[:, 0], axis=0)
        for c in range(1, cfg.n_codebooks):
            embs = embs + jnp.take(params["embed"][c], toks[:, c], axis=0)
        return embs.astype(cfg.act_dtype)
    raise ValueError(cfg.modality)


def _positions(cfg: ArchConfig, batch: Dict[str, Array], B: int, S: int,
               offset: Array | int = 0) -> Array:
    if cfg.modality == "vlm":
        return batch["positions"]  # (B, 3, S)
    pos = offset + jnp.arange(S)
    return jnp.broadcast_to(pos[None, :], (B, S))


def unembed(cfg: ArchConfig, params: dict, hidden: Array) -> Array:
    if cfg.modality == "text" and cfg.tie_embeddings:
        logits = hidden.astype(jnp.float32) @ params["embed"].T.astype(
            jnp.float32)
    else:
        logits = (hidden @ params["lm_head"]).astype(jnp.float32)
    if cfg.modality == "audio":
        B, S, _ = hidden.shape
        logits = logits.reshape(B, S, cfg.n_codebooks, cfg.vocab)
    return constrain(logits, "logits_btv")


# ------------------------------------------------------------------ loss

def per_example_loss(cfg: ArchConfig, logits: Array, batch
                     ) -> Tuple[Array, Array]:
    """Mean CE per example: ((B,), valid-token counts)."""
    labels = batch["labels"]
    if cfg.modality == "audio":
        # labels (B, C, S) -> align with logits (B, S, C, V)
        labels = jnp.swapaxes(labels, 1, 2)
    valid = (labels >= 0)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    tok_loss = -tok_ll * valid
    axes = tuple(range(1, tok_loss.ndim))
    n = jnp.maximum(jnp.sum(valid, axis=axes), 1)
    return jnp.sum(tok_loss, axis=axes) / n, n


def sigma_scores(cfg: ArchConfig, hidden: Array, logits: Array,
                 batch) -> Array:
    """Per-example last-layer gradient-norm^2 proxy (GraNd-style):
    sum_t ||softmax - onehot||^2 * (||h_t||^2 + 1).  Exact per token;
    the cross-token outer-product terms of the full-sequence last-layer
    norm are dropped (documented adaptation — O(S) not O(S^2))."""
    labels = batch["labels"]
    if cfg.modality == "audio":
        labels = jnp.swapaxes(labels, 1, 2)
    valid = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    p = jax.nn.softmax(logits, axis=-1)
    py = jnp.take_along_axis(p, safe[..., None], axis=-1)[..., 0]
    dnorm2 = jnp.sum(p * p, axis=-1) - 2.0 * py + 1.0  # ||p - y||^2
    if cfg.modality == "audio":
        dnorm2 = jnp.sum(dnorm2 * valid, axis=-1)  # sum codebooks
        valid = valid[..., 0]
    else:
        dnorm2 = dnorm2 * valid
    h2 = jnp.sum(jnp.square(hidden.astype(jnp.float32)), axis=-1) + 1.0
    axes = tuple(range(1, dnorm2.ndim))
    return jnp.sum(dnorm2 * h2, axis=axes) / jnp.maximum(
        jnp.sum(valid, axis=axes), 1.0)


# ----------------------------------------------------------- FEEL wiring

@dataclasses.dataclass(frozen=True)
class FeelIntegration:
    """Paper technique inside the train step.

    ``n_clients`` data-parallel groups act as the K federated devices;
    ``eps`` is each client's availability probability (eq. 19 weights);
    selection is the exact Problem-4 solver over per-example sigmas.
    """
    n_clients: int
    eps: float = 0.8
    lam: float = 1e-3
    q_reward: float = 0.002

    def system(self, per_client: int) -> SystemParams:
        K = self.n_clients
        return SystemParams(
            K=K, N=max(K // 2, 1), Q=2,
            B=jnp.asarray(2e6), T=jnp.asarray(0.5), L=jnp.asarray(1e6),
            N0=jnp.asarray(1e-9), p_max=jnp.full((K,), 10.0),
            q=jnp.full((K,), self.q_reward), c=jnp.full((K,), 5.0),
            f=jnp.full((K,), 1e9), F=jnp.full((K,), 20.0),
            kappa=jnp.asarray(1e-28), eps=jnp.full((K,), self.eps),
            D_hat=jnp.full((K,), float(per_client)),
            lam=jnp.asarray(self.lam))


# ------------------------------------------------------------ step fns

def make_forward(cfg: ArchConfig):
    def forward(params, batch):
        x = embed_input(cfg, params, batch)
        B, S = x.shape[:2]
        pos = _positions(cfg, batch, B, S)
        hidden, _, aux = apply_decoder(cfg, params["decoder"], x, pos,
                                       mode="train")
        return unembed(cfg, params, hidden), hidden, aux

    return forward


def make_train_step(cfg: ArchConfig, opt: GradientTransformation,
                    feel: Optional[FeelIntegration] = None):
    """Returns train_step(params, opt_state, batch) -> (params,
    opt_state, metrics).  With ``feel``, batch must carry "alpha"
    (n_clients,) availability indicators."""
    forward = make_forward(cfg)

    def loss_fn(params, batch):
        logits, hidden, aux = forward(params, batch)
        ex_loss, _ = per_example_loss(cfg, logits, batch)
        B = ex_loss.shape[0]
        metrics = {}
        if feel is None:
            loss = jnp.mean(ex_loss)
            metrics["selected_frac"] = jnp.asarray(1.0)
        else:
            K = feel.n_clients
            per_client = B // K
            sigma = jax.lax.stop_gradient(
                sigma_scores(cfg, hidden, logits, batch))
            sig_k = sigma.reshape(K, per_client)
            sys_k = feel.system(per_client)
            delta = sel_mod.exact_selection(
                sys_k, sig_k, jnp.ones_like(sig_k))  # (K, per_client)
            m_k = jnp.maximum(jnp.sum(delta, axis=1), 1.0)
            alpha = batch["alpha"].astype(jnp.float32)  # (K,)
            # eq. (19): (1/|D̂|) * (|D̂_k|/eps_k) * alpha_k * mean_sel
            w_k = (per_client / feel.eps) * alpha / (K * per_client)
            # per-sample weight: w_k * delta / m_k; summing gives
            # (1/K) sum_k (alpha_k/eps) mean_selected(loss_k) — an
            # unbiased estimate of the mean loss (Lemma 1)
            w = (delta * (w_k / m_k)[:, None]).reshape(B)
            loss = jnp.sum(w * ex_loss)
            metrics["selected_frac"] = jnp.mean(delta)
            metrics["sigma_mean"] = jnp.mean(sigma)
        total = loss + aux
        metrics["loss"] = loss
        metrics["aux_loss"] = aux
        return total, metrics

    def train_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        x = embed_input(cfg, params, batch)
        B, S = x.shape[:2]
        pos = _positions(cfg, batch, B, S)
        hidden, cache, _ = apply_decoder(cfg, params["decoder"], x, pos,
                                         mode="prefill")
        logits = unembed(cfg, params, hidden[:, -1:])
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, mla_absorbed: bool = False):
    """serve_step: one new token against a seq_len-sized cache."""

    def decode_step(params, cache, batch):
        x = embed_input(cfg, params, batch)
        B = x.shape[0]
        idx = batch["cache_index"]  # scalar int32
        pos = (batch["positions"] if cfg.modality == "vlm"
               else jnp.broadcast_to(idx[None, None], (B, 1)))
        hidden, new_cache, _ = apply_decoder(
            cfg, params["decoder"], x, pos, mode="decode", cache=cache,
            cache_index=idx, mla_absorbed=mla_absorbed)
        logits = unembed(cfg, params, hidden)
        return logits, new_cache

    return decode_step


make_cache = init_cache  # re-export with the model-level name
