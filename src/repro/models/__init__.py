from . import cnn  # paper-scale CNN (§VI-A)
from .config import ArchConfig
from .model import (FeelIntegration, init_model, make_cache,
                    make_decode_step, make_forward, make_prefill_step,
                    make_train_step, param_count)

__all__ = ["ArchConfig", "cnn", "init_model", "make_cache",
           "make_decode_step", "make_forward", "make_prefill_step",
           "make_train_step", "param_count", "FeelIntegration"]
