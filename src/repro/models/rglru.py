"""RG-LRU recurrent mixer (RecurrentGemma / Griffin).

    r_t = sigmoid(W_r x_t)                      (recurrence gate)
    i_t = sigmoid(W_i x_t)                      (input gate)
    log a_t = -c * softplus(Lambda) * r_t       (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Diagonal linear recurrence -> associative scan (same TPU adaptation as
the Mamba mixer).  The block is the Griffin recurrent block: dual
linear branches, a short causal conv on the recurrent branch, RG-LRU,
GeLU-gated merge, output projection.

Cache: {"conv": (B, k-1, w), "h": (B, w)}.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import init_dense
from .shard_ctx import constrain

Array = jax.Array

_C = 8.0


def init_rglru(key, cfg: ArchConfig, dtype) -> dict:
    d, w, k = cfg.d_model, cfg.lru_width_, cfg.ssm_conv or 4
    ks = jax.random.split(key, 6)
    # Lambda init so a^c covers (0.9, 0.999) — standard Griffin init
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u ** (1.0 / _C))))
    return {
        "w_x": init_dense(ks[0], d, w, dtype),
        "w_y": init_dense(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (k, w), jnp.float32)
                   * (1.0 / k ** 0.5)).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": init_dense(ks[3], w, w, dtype),
        "w_i": init_dense(ks[5], w, w, dtype),
        "lam": lam,
        "w_out": init_dense(jax.random.fold_in(key, 7), w, d, dtype),
    }


def _gates(p: dict, s: Array):
    r = jax.nn.sigmoid((s @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((s @ p["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * i


def rglru_mixer(cfg: ArchConfig, p: dict, x: Array, mode: str,
                cache: Optional[dict]) -> Tuple[Array, Optional[dict]]:
    B, S, _ = x.shape
    k = cfg.ssm_conv or 4
    xs = x @ p["w_x"]
    gate = jax.nn.gelu((x @ p["w_y"]).astype(jnp.float32))
    xs = constrain(xs, "act_btf")

    if mode in ("train", "prefill"):
        pad = jnp.pad(xs, ((0, 0), (k - 1, 0), (0, 0)))
        conv = sum(pad[:, j:j + S, :] * p["conv_w"][j]
                   for j in range(k)) + p["conv_b"]
        a, bx_scale = _gates(p, conv)
        bx = bx_scale * conv.astype(jnp.float32)

        def combine(u, v):
            a1, b1 = u
            a2, b2 = v
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
        new_cache = None
        if mode == "prefill":
            # last k-1 inputs, zero-left-padded when S < k-1
            xp = jnp.pad(xs, ((0, 0), (max(k - 1 - S, 0), 0), (0, 0)))
            new_cache = {"conv": xp[:, xp.shape[1] - (k - 1):, :],
                         "h": h[:, -1]}
    else:
        assert cache is not None
        conv_buf = jnp.concatenate(
            [cache["conv"], xs.astype(cache["conv"].dtype)], axis=1)
        conv = (jnp.einsum("bkw,kw->bw", conv_buf, p["conv_w"])
                + p["conv_b"])[:, None, :]
        a, bx_scale = _gates(p, conv)
        h1 = a[:, 0] * cache["h"] + (bx_scale * conv.astype(jnp.float32))[:, 0]
        h = h1[:, None, :]
        new_cache = {"conv": conv_buf[:, 1:, :], "h": h1}

    y = (h * gate).astype(x.dtype) @ p["w_out"]
    return y, new_cache
