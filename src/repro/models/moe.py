"""Mixture-of-Experts FFN (DeepSeek-V2/V3 style: shared + routed
experts, token-choice top-k routing, normalized gates).

TPU adaptation (DESIGN.md §2): dispatch is *capacity-based gather*
rather than a (tokens x experts x capacity) one-hot einsum — each
expert takes the top-C tokens that routed to it (priority by gate
value), giving fixed shapes, MXU-aligned per-expert matmuls, and an
expert-sharded (E, C, d) working set.  Tokens beyond capacity are
dropped (standard drop policy; capacity_factor controls slack).
The expert dim E shards over the mesh "model" axis (expert
parallelism) — the gather/scatter lower to the all-to-all-like
collectives the roofline analysis attributes to MoE.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import init_dense, mlp
from .shard_ctx import constrain

Array = jax.Array


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / (d ** 0.5)
    p = {
        "router": init_dense(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                   * (1.0 / f ** 0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        from .layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared_experts * f, dtype)
    return p


def capacity(cfg: ArchConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.topk / cfg.n_experts * cfg.capacity_factor)
    return min(max(8, -(-c // 8) * 8), n_tokens)  # 8-aligned, <= tokens


def moe_ffn(cfg: ArchConfig, p: dict, x: Array) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    G = B * S
    E, K = cfg.n_experts, cfg.topk
    xf = x.reshape(G, d)

    # matmul in the activation dtype so the (G, d) gradient flowing
    # back through the router stays bf16 (halves the dispatch-grad
    # all-reduce, §Perf pair B iter 3); softmax still f32.
    router_logits = (xf @ p["router"].astype(xf.dtype)
                     ).astype(jnp.float32)  # (G, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, K)  # (G, K)
    top_vals = top_vals / jnp.maximum(
        jnp.sum(top_vals, -1, keepdims=True), 1e-9)  # DeepSeek normalization

    # gate matrix (G, E): gate value where expert chosen, else 0
    gate_mat = jnp.zeros((G, E), jnp.float32).at[
        jnp.arange(G)[:, None], top_idx].set(top_vals)

    # ---- expert-side capacity selection (priority = gate value) ----
    C = capacity(cfg, G)
    w_ec, idx_ec = jax.lax.top_k(gate_mat.T, C)  # (E, C) over tokens
    x_ec = jnp.take(xf, idx_ec, axis=0)  # (E, C, d)
    x_ec = constrain(x_ec, "moe_ecd")

    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", x_ec, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", x_ec, p["w_up"])
    y_ec = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # (E, C, d)
    y_ec = constrain(y_ec, "moe_ecd")

    # ---- combine: scatter-add back to tokens, weighted by gates ----
    # keep the combine in the activation dtype: a f32 combine promotes
    # the cross-expert all-reduce to f32 and doubles its bytes
    # (measured 37.6 GB/layer -> see EXPERIMENTS.md §Perf pair B)
    contrib = (y_ec.astype(x.dtype)
               * w_ec[..., None].astype(x.dtype)).reshape(E * C, d)
    yf = jnp.zeros((G, d), x.dtype).at[idx_ec.reshape(-1)].add(
        contrib, mode="drop")

    if cfg.n_shared_experts:
        yf = yf + mlp(p["shared"], xf, cfg.act)

    # ---- switch-style load-balance auxiliary loss ----
    me = jnp.mean(probs, axis=0)                      # router mass / expert
    ce = jnp.mean(gate_mat > 0, axis=0)               # token fraction / expert
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)
    return yf.reshape(B, S, d), aux
