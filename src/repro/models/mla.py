"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are produced through low-rank bottlenecks; the
KV cache stores only the compressed latent c_kv (kv_lora dims) plus the
shared rotary key k_rope — the paper-family's memory win for decode.

Two decode paths:
  * naive   — expand k/v from the cached latent every step (simple,
              verifiable against prefill);
  * absorbed — fold W_uk into the query and W_uv into the output
              projection so attention runs directly in the latent
              space; per-step FLOPs drop from O(S * kv_lora * H * dh)
              (re-expansion) to O(S * H * kv_lora) (score/ctx einsums).
              This is the §Perf-tracked optimization for decode shapes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import _NEG_INF, apply_rope, init_dense, rmsnorm
from .shard_ctx import constrain

Array = jax.Array


def init_mla(key, cfg: ArchConfig, dtype) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    nope, rope_d, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.q_lora:
        p["w_dq"] = init_dense(ks[0], d, cfg.q_lora, dtype)
        p["q_norm"] = jnp.ones((cfg.q_lora,), dtype)
        p["w_uq"] = init_dense(ks[1], cfg.q_lora, H * (nope + rope_d), dtype)
    else:
        p["w_q"] = init_dense(ks[1], d, H * (nope + rope_d), dtype)
    p["w_dkv"] = init_dense(ks[2], d, cfg.kv_lora, dtype)
    p["kv_norm"] = jnp.ones((cfg.kv_lora,), dtype)
    p["w_uk"] = init_dense(ks[3], cfg.kv_lora, H * nope, dtype)
    p["w_uv"] = init_dense(ks[4], cfg.kv_lora, H * vdim, dtype)
    p["w_kr"] = init_dense(ks[5], d, rope_d, dtype)
    p["w_o"] = init_dense(ks[6], H * vdim, d, dtype)
    return p


def _queries(cfg: ArchConfig, p: dict, x: Array, positions: Array):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora:
        cq = rmsnorm(x @ p["w_dq"], p["q_norm"])
        q = (cq @ p["w_uq"]).reshape(B, S, H, nope + rope_d)
    else:
        q = (x @ p["w_q"]).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(cfg: ArchConfig, p: dict, x: Array, positions: Array):
    """Compressed latent (already normed) + roped shared key."""
    ckv = rmsnorm(x @ p["w_dkv"], p["kv_norm"])  # (B, S, kv_lora)
    kr = (x @ p["w_kr"])[:, :, None, :]  # (B, S, 1, rope_d)
    kr = apply_rope(kr, positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, kr


def mla_attention(cfg: ArchConfig, p: dict, x: Array, positions: Array,
                  mode: str, cache: Optional[dict], cache_index,
                  absorbed: bool = False) -> Tuple[Array, Optional[dict]]:
    """Returns (attn_out (B,S,d), new_cache)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = (nope + rope_d) ** -0.5
    q_nope, q_rope = _queries(cfg, p, x, positions)

    if mode in ("train", "prefill"):
        from .layers import causal_attend
        ckv, kr = _latents(cfg, p, x, positions)
        new_cache = None
        if mode == "prefill":
            new_cache = {"ckv": ckv, "kr": kr}
        k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, nope)
        v = (ckv @ p["w_uv"]).reshape(B, S, H, vdim)
        # fold the shared rotary key in as extra head dims so the
        # q-chunked attention path (bounded memory at 32k) applies
        q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_eff = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                      (B, S, H, rope_d))], axis=-1)
        out = causal_attend(q_eff, k_eff, v, scale=scale)
        y = out.reshape(B, S, H * vdim) @ p["w_o"]
        return y, new_cache

    assert mode == "decode" and cache is not None
    ckv_new, kr_new = _latents(cfg, p, x, positions)
    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype),
        (0, cache_index, 0))
    kr = jax.lax.dynamic_update_slice(
        cache["kr"], kr_new.astype(cache["kr"].dtype), (0, cache_index, 0))
    new_cache = {"ckv": ckv, "kr": kr}
    Sc = ckv.shape[1]
    valid = (jnp.arange(Sc) <= cache_index)[None, None, None, :]

    rope_scores = jnp.einsum("bqhd,bkd->bhqk", q_rope, kr,
                             preferred_element_type=jnp.float32)
    if absorbed:
        # fold W_uk into q: (B,1,H,nope) x (kv_lora, H, nope) -> latent q
        w_uk = p["w_uk"].reshape(cfg.kv_lora, H, nope)
        q_lat = jnp.einsum("bqhn,chn->bqhc", q_nope, w_uk)
        q_lat = constrain(q_lat, "act_bthd")
        scores = jnp.einsum("bqhc,bkc->bhqk", q_lat, ckv,
                            preferred_element_type=jnp.float32)
        logits = (scores + rope_scores) * scale
        logits = jnp.where(valid, logits, _NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(ckv.dtype)
        ctx = jnp.einsum("bhqk,bkc->bqhc", probs, ckv)  # latent context
        w_uv = p["w_uv"].reshape(cfg.kv_lora, H, vdim)
        out = jnp.einsum("bqhc,chv->bqhv", ctx, w_uv)
    else:
        k_nope = (ckv @ p["w_uk"]).reshape(B, Sc, H, nope)
        v = (ckv @ p["w_uv"]).reshape(B, Sc, H, vdim)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                            preferred_element_type=jnp.float32)
        logits = (scores + rope_scores) * scale
        logits = jnp.where(valid, logits, _NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    y = out.reshape(B, 1, H * vdim) @ p["w_o"]
    return y, new_cache
