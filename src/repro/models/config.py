"""Architecture configuration.

One frozen dataclass describes every assigned architecture; the
decoder in ``transformer.py`` is driven entirely by it.  Layer layout
is expressed as a repeating ``layer_pattern`` of block kinds:

    "attn"        global causal attention + (dense or MoE) FFN
    "attn_local"  sliding-window attention + FFN
    "mla"         multi-head latent attention (DeepSeek) + FFN
    "mamba"       Mamba-1 selective-SSM mixer (no separate FFN)
    "rglru"       RG-LRU recurrent mixer + FFN

The pattern repeats floor(L / len(pattern)) times (lowered as a
jax.lax.scan over stacked parameters); the L % len(pattern) remainder
layers are applied unrolled from the pattern prefix.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # --- layer layout -------------------------------------------------
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0               # sliding window for attn_local
    ffn_in_pattern: bool = True   # mamba blocks have no FFN

    # --- attention ----------------------------------------------------
    head_dim: Optional[int] = None    # default d_model // n_heads
    rope_theta: float = 1e4
    rope_theta_local: Optional[float] = None  # gemma3 local layers
    rope_fraction: float = 1.0        # stablelm partial rotary
    qk_norm: bool = False             # gemma3
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) split
    attn_logit_softcap: float = 0.0

    # --- MoE ------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    topk: int = 0
    moe_d_ff: int = 0
    first_dense: int = 0          # leading dense layers (DeepSeek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-3

    # --- MLA ------------------------------------------------------------
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- SSM (mamba-1) ----------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0          # default ceil(d_model / 16)

    # --- RG-LRU -----------------------------------------------------------
    lru_width: int = 0            # default d_model

    # --- modality ---------------------------------------------------------
    modality: str = "text"        # text | vlm | audio
    n_codebooks: int = 4          # audio codebooks (musicgen)

    # --- lowering -----------------------------------------------------------
    # scan-over-layers keeps HLO small (fast compiles); the dry-run
    # sets unroll_layers=True because XLA cost_analysis counts a scan
    # body once — unrolling makes HLO_FLOPs/collective_bytes exact.
    # scan_unroll=k partially unrolls (k body copies per iteration):
    # the dry-run compiles k=1 and k=2 and extrapolates exact totals
    # (F(k) = outside + k*body is affine in k).
    unroll_layers: bool = False
    scan_unroll: int = 1

    # --- numerics / training ----------------------------------------------
    dtype: str = "bfloat16"
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    remat: bool = True
    use_bias: bool = False
    norm: str = "rmsnorm"
    act: str = "silu"             # silu (gated) | gelu (gated)
    tie_embeddings: bool = False
    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_dt_rank_(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    @property
    def n_pattern_repeats(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def n_tail_layers(self) -> int:
        return self.n_layers % len(self.layer_pattern)

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    def scaled(self, **overrides) -> "ArchConfig":
        """Reduced variant of the same family (smoke tests)."""
        return dataclasses.replace(self, **overrides)

    def validate(self) -> None:
        assert self.n_layers >= 1
        if "mla" in self.layer_pattern:
            assert self.kv_lora > 0
        if self.n_experts:
            assert self.topk > 0 and self.moe_d_ff > 0
        if "attn_local" in self.layer_pattern:
            assert self.window > 0
        if self.modality == "vlm":
            assert len(self.mrope_sections) == 3
