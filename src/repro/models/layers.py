"""Shared neural-net layers: norms, RoPE (incl. M-RoPE), gated MLP,
and GQA attention with global / sliding-window / cached-decode paths.

All functions are pure; parameters are plain dict pytrees.  Attention
uses q-chunking for long sequences (bounded memory, flash-style
blocking — the Pallas kernel in kernels/flash_attention.py is the TPU
version of the same schedule; this jnp path is what the dry-run lowers
so cost_analysis sees real FLOPs).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig

Array = jax.Array

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ------------------------------------------------------------------ norms

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, dtype) -> Array:
    scale = 1.0 / (d_in ** 0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


# ------------------------------------------------------------------- rope

def _rope_cos_sin(positions: Array, n_pairs: int, theta: float,
                  mrope_sections: Tuple[int, ...] = ()):
    """cos/sin tables: positions (B,S) or (B,3,S) for M-RoPE.

    Returns (B, S, n_pairs) float32 cos and sin.
    """
    freqs = theta ** (-jnp.arange(n_pairs, dtype=jnp.float32) / n_pairs)
    if positions.ndim == 2:  # standard 1-D rope
        ang = positions[..., None].astype(jnp.float32) * freqs
    else:
        # M-RoPE (Qwen2-VL): pair i takes its position id from the
        # (temporal|height|width) section it belongs to.
        assert sum(mrope_sections) == n_pairs, (mrope_sections, n_pairs)
        sec_id = jnp.repeat(
            jnp.arange(len(mrope_sections)),
            jnp.asarray(mrope_sections),
            total_repeat_length=n_pairs)  # (n_pairs,) in {0,1,2}
        pos = jnp.take(positions, sec_id, axis=1)  # (B, n_pairs, S)
        ang = jnp.swapaxes(pos, 1, 2).astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, positions: Array, theta: float,
               fraction: float = 1.0,
               mrope_sections: Tuple[int, ...] = ()) -> Array:
    """x: (B, S, H, Dh). Rotates the first ``fraction * Dh`` dims."""
    d = x.shape[-1]
    d_rot = int(d * fraction)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    n_pairs = d_rot // 2
    cos, sin = _rope_cos_sin(positions, n_pairs, theta, mrope_sections)
    cos = cos[:, :, None, :]  # (B, S, 1, n_pairs)
    sin = sin[:, :, None, :]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., :n_pairs], xr[..., n_pairs:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin,
                           x2f * cos + x1f * sin], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if d - d_rot else out


# ------------------------------------------------------------------- mlp

def init_mlp(key, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": init_dense(k1, d, f, dtype),
            "w_up": init_dense(k2, d, f, dtype),
            "w_down": init_dense(k3, f, d, dtype)}


def mlp(p: dict, x: Array, act: str = "silu") -> Array:
    a = jax.nn.silu if act == "silu" else functools.partial(
        jax.nn.gelu, approximate=True)
    return (a(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# -------------------------------------------------------------- attention

def init_attention(key, cfg: ArchConfig, dtype) -> dict:
    d, H, Hk, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {"wq": init_dense(ks[0], d, H * Dh, dtype),
         "wk": init_dense(ks[1], d, Hk * Dh, dtype),
         "wv": init_dense(ks[2], d, Hk * Dh, dtype),
         "wo": init_dense(ks[3], H * Dh, d, dtype)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), dtype)
        p["k_norm"] = jnp.ones((Dh,), dtype)
    return p


def _gqa_split(q: Array, n_kv: int) -> Array:
    """(B, S, H, Dh) -> (B, S, Hk, G, Dh): grouped-query layout.

    All attention helpers are GQA-native: keys/values keep their Hk
    heads and queries carry an extra group dim, so the repeated KV is
    never materialized (matters for 32k+ caches)."""
    B, S, H, Dh = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, Dh)


def _softmax_attend(q: Array, k: Array, v: Array, mask: Array,
                    scale: float, softcap: float = 0.0) -> Array:
    """q: (B,Sq,Hk,G,Dh), k: (B,Sk,Hk,Dh), v: (B,Sk,Hk,Dv);
    mask (1|B, 1, 1, Sq, Sk) bool. Returns (B,Sq,Hk*G,Dv)."""
    B, Sq, Hk, G, _ = q.shape
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask, logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, Hk * G, v.shape[-1])


def causal_attend(q: Array, k: Array, v: Array, q_offset: int | Array = 0,
                  window: int = 0, scale: Optional[float] = None,
                  softcap: float = 0.0, q_chunk: int = 1024) -> Array:
    """Causal (optionally windowed) GQA attention with q-chunking.

    q: (B,Sq,H,Dh); k/v: (B,Sk,Hk,·).  q positions are
    ``q_offset + arange(Sq)``; k positions ``arange(Sk)``.
    ``window > 0`` limits attention to the last ``window`` keys.
    """
    B, Sq, H, Dh = q.shape
    Hk = k.shape[2]
    Sk = k.shape[1]
    scale = scale if scale is not None else Dh ** -0.5
    kpos = jnp.arange(Sk)
    qg = _gqa_split(q, Hk)

    def chunk_attend(args):
        qc, qpos = args
        mask = qpos[:, None] >= kpos[None, :]
        if window > 0:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        return _softmax_attend(qc, k, v, mask[None, None, None],
                               scale, softcap)

    if Sq <= q_chunk:
        qpos = q_offset + jnp.arange(Sq)
        return chunk_attend((qg, qpos))

    n_chunks = -(-Sq // q_chunk)
    pad = n_chunks * q_chunk - Sq
    qp = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qpos = q_offset + jnp.arange(n_chunks * q_chunk)
    qcs = jnp.moveaxis(
        qp.reshape(B, n_chunks, q_chunk, Hk, H // Hk, Dh), 1, 0)
    out = jax.lax.map(chunk_attend,
                      (qcs, qpos.reshape(n_chunks, q_chunk)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * q_chunk, H,
                                          v.shape[-1])
    return out[:, :Sq]


def local_attend_chunked(q: Array, k: Array, v: Array, window: int,
                         scale: Optional[float] = None,
                         softcap: float = 0.0) -> Array:
    """Sliding-window causal GQA attention in O(S * window) memory.

    Sequence is cut into window-sized chunks; each chunk attends to
    itself and the previous chunk with an exact banded mask.
    """
    B, S, H, Dh = q.shape
    Hk = k.shape[2]
    G = H // Hk
    Dv = v.shape[-1]
    scale = scale if scale is not None else Dh ** -0.5
    W = window
    n = -(-S // W)
    pad = n * W - S

    def padded(x):
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qc = padded(q).reshape(B, n, W, Hk, G, Dh)
    kc = padded(k).reshape(B, n, W, Hk, Dh)
    vc = padded(v).reshape(B, n, W, Hk, Dv)
    # keys for chunk i: chunks (i-1, i)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kc], axis=2)  # (B, n, 2W, Hk, Dh)
    v2 = jnp.concatenate([v_prev, vc], axis=2)

    qpos = jnp.arange(W)
    kpos = jnp.arange(2 * W) - W  # relative to chunk start
    mask = (kpos[None, :] <= qpos[:, None]) & \
           (kpos[None, :] > qpos[:, None] - W)  # (W, 2W)
    # first chunk must not see the (zero) previous chunk
    first_mask = mask & (kpos[None, :] >= 0)
    masks = jnp.where(jnp.arange(n)[:, None, None] == 0, first_mask[None],
                      mask[None])  # (n, W, 2W)

    logits = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qc, k2,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(masks[:, None, None, :][None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", probs, v2)
    return out.reshape(B, n * W, H, Dv)[:, :S]


def decode_attend(q: Array, k_cache: Array, v_cache: Array,
                  cache_index: Array, window: int = 0,
                  rolling: bool = False, scale: Optional[float] = None,
                  softcap: float = 0.0) -> Array:
    """Single-token GQA decode attention over a (possibly rolling) cache.

    q: (B, 1, H, Dh); caches: (B, C, Hk, ·) (NOT head-repeated).
    ``cache_index``: the new token's position.  For rolling caches
    (local attention), slot t of the buffer holds absolute position
    i - ((i - t) mod C) after writing token i at slot i % C.
    """
    B, _, H, Dh = q.shape
    Hk = k_cache.shape[2]
    C = k_cache.shape[1]
    scale = scale if scale is not None else Dh ** -0.5
    slots = jnp.arange(C)
    if rolling:
        i = cache_index
        pos = i - jnp.mod(i - slots, C)
        valid = pos >= 0
        if window > 0:
            valid &= pos > i - window
    else:
        valid = slots <= cache_index
        if window > 0:
            valid &= slots > cache_index - window
    mask = valid[None, None, None, None, :]  # (1,1,1,1,C)
    qg = _gqa_split(q, Hk)
    return _softmax_attend(qg, k_cache, v_cache, mask, scale, softcap)
