"""Decoder assembly: blocks, repeating-pattern scan, caches.

Layer layout = ``first_dense`` unrolled head layers (DeepSeek's dense
lead-in), then floor((L - first_dense)/P) repetitions of the
``layer_pattern`` lowered as ONE ``jax.lax.scan`` over stacked params
(small HLO, fast multi-pod compiles), then the remainder layers
unrolled from the pattern prefix.

Block kinds and their caches:
    attn        {"k","v"}: (B, S_ctx, Hk, Dh)
    attn_local  {"k","v"}: (B, window, Hk, Dh)  rolling buffer
    mla         {"ckv": (B,S,kv_lora), "kr": (B,S,rope_d)}
    mamba       {"conv": (B,k-1,d_in), "h": (B,d_in,n)}
    rglru       {"conv": (B,k-1,w), "h": (B,w)}
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (apply_rope, causal_attend, decode_attend, init_attention,
                     init_mlp, local_attend_chunked, mlp, rmsnorm)
from .mla import init_mla, mla_attention
from .moe import init_moe, moe_ffn
from .rglru import init_rglru, rglru_mixer
from .ssm import init_mamba, mamba_mixer
from .shard_ctx import constrain

Array = jax.Array


# ------------------------------------------------------------------ blocks

def init_block(key, cfg: ArchConfig, kind: str, use_moe: bool,
               dense_ff: Optional[int] = None) -> dict:
    dtype = cfg.act_dtype
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"ln1": jnp.ones((d,), dtype)}
    if kind in ("attn", "attn_local"):
        p["attn"] = init_attention(k1, cfg, dtype)
    elif kind == "mla":
        p["attn"] = init_mla(k1, cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = init_mamba(k1, cfg, dtype)
        return p  # mamba blocks have no separate FFN
    elif kind == "rglru":
        p["mixer"] = init_rglru(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    p["ln2"] = jnp.ones((d,), dtype)
    if use_moe:
        p["ffn"] = init_moe(k2, cfg, dtype)
    else:
        p["ffn"] = init_mlp(k2, d, dense_ff or cfg.d_ff, dtype)
    return p


def _attn_apply(cfg: ArchConfig, kind: str, p: dict, x: Array,
                positions: Array, mode: str, cache, cache_index,
                mla_absorbed: bool):
    """Attention sublayer dispatch; returns (out, new_cache)."""
    if kind == "mla":
        return mla_attention(cfg, p["attn"], x, positions, mode, cache,
                             cache_index, absorbed=mla_absorbed)

    B, S, _ = x.shape
    H, Hk, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ap = p["attn"]
    local = kind == "attn_local"
    theta = (cfg.rope_theta_local
             if local and cfg.rope_theta_local else cfg.rope_theta)
    softcap = cfg.attn_logit_softcap

    q = (x @ ap["wq"]).reshape(B, S, H, Dh)
    k = (x @ ap["wk"]).reshape(B, S, Hk, Dh)
    v = (x @ ap["wv"]).reshape(B, S, Hk, Dh)
    if cfg.qk_norm:
        q = rmsnorm(q, ap["q_norm"])
        k = rmsnorm(k, ap["k_norm"])
    q = apply_rope(q, positions, theta, cfg.rope_fraction,
                   cfg.mrope_sections)
    k = apply_rope(k, positions, theta, cfg.rope_fraction,
                   cfg.mrope_sections)
    q = constrain(q, "act_bthd")

    new_cache = None
    if mode == "train":
        if local:
            out = local_attend_chunked(q, k, v, cfg.window, softcap=softcap)
        else:
            out = causal_attend(q, k, v, softcap=softcap)
    elif mode == "prefill":
        if local:
            W = cfg.window
            out = local_attend_chunked(q, k, v, W, softcap=softcap)
            # rolling cache holds the last W tokens at slot pos % W
            take = min(S, W)
            kw = k[:, S - take:]
            vw = v[:, S - take:]
            slots = jnp.mod(jnp.arange(S - take, S), W)
            k_buf = jnp.zeros((B, W, Hk, Dh), k.dtype).at[:, slots].set(kw)
            v_buf = jnp.zeros((B, W, Hk, Dh), v.dtype).at[:, slots].set(vw)
            new_cache = {"k": k_buf, "v": v_buf}
        else:
            out = causal_attend(q, k, v, softcap=softcap)
            new_cache = {"k": constrain(k, "kv_cache"),
                         "v": constrain(v, "kv_cache")}
    else:  # decode
        assert cache is not None
        C = cache["k"].shape[1]
        slot = jnp.mod(cache_index, C) if local else cache_index
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        kc = constrain(kc, "kv_cache")
        vc = constrain(vc, "kv_cache")
        new_cache = {"k": kc, "v": vc}
        out = decode_attend(q, kc, vc, cache_index,
                            window=cfg.window if local else 0,
                            rolling=local, softcap=softcap)
    y = out.reshape(B, S, H * Dh) @ ap["wo"]
    return y, new_cache


def apply_block(cfg: ArchConfig, kind: str, use_moe: bool, p: dict,
                x: Array, positions: Array, mode: str, cache,
                cache_index, mla_absorbed: bool = False
                ) -> Tuple[Array, Any, Array]:
    """Pre-norm residual block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["ln1"])
    if kind in ("attn", "attn_local", "mla"):
        y, new_cache = _attn_apply(cfg, kind, p, h, positions, mode, cache,
                                   cache_index, mla_absorbed)
    elif kind == "mamba":
        y, new_cache = mamba_mixer(cfg, p["mixer"], h, mode, cache)
        return constrain(x + y, "act_btd"), new_cache, aux
    elif kind == "rglru":
        y, new_cache = rglru_mixer(cfg, p["mixer"], h, mode, cache)
    else:
        raise ValueError(kind)
    x = x + y
    h = rmsnorm(x, p["ln2"])
    if use_moe:
        f, aux = moe_ffn(cfg, p["ffn"], h)
    else:
        f = mlp(p["ffn"], h, cfg.act)
    return constrain(x + f, "act_btd"), new_cache, aux


# ----------------------------------------------------------- decoder stack

def _layer_plan(cfg: ArchConfig):
    """(head_kinds, n_body, pattern, tail_kinds)."""
    P = len(cfg.layer_pattern)
    fd = cfg.first_dense
    L_rest = cfg.n_layers - fd
    n_body = L_rest // P
    tail = cfg.layer_pattern[:L_rest % P]
    head = tuple(cfg.layer_pattern[i % P] for i in range(fd))
    return head, n_body, cfg.layer_pattern, tail


def _uses_moe(cfg: ArchConfig) -> bool:
    return cfg.n_experts > 0


def init_decoder(key, cfg: ArchConfig) -> dict:
    head, n_body, pattern, tail = _layer_plan(cfg)
    ks = jax.random.split(key, 4)
    params: dict = {}
    # DeepSeek's dense lead-in layers use the wide dense FFN
    dense_ff = cfg.d_ff if not _uses_moe(cfg) else None
    params["head"] = [
        init_block(jax.random.fold_in(ks[0], i), cfg, kind, use_moe=False,
                   dense_ff=cfg.d_ff)
        for i, kind in enumerate(head)]
    body = {}
    for pos, kind in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(ks[1], pos), n_body)
        body[f"pos{pos}"] = jax.vmap(
            lambda k: init_block(k, cfg, kind, use_moe=_uses_moe(cfg),
                                 dense_ff=dense_ff))(keys)
    params["body"] = body
    params["tail"] = [
        init_block(jax.random.fold_in(ks[2], 100 + i), cfg, kind,
                   use_moe=_uses_moe(cfg), dense_ff=dense_ff)
        for i, kind in enumerate(tail)]
    params["final_norm"] = jnp.ones((cfg.d_model,), cfg.act_dtype)
    return params


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    """Zero-filled cache pytree matching the decoder layout."""
    dtype = dtype or cfg.act_dtype
    head, n_body, pattern, tail = _layer_plan(cfg)

    def one(kind):
        B = batch
        Hk, Dh = cfg.n_kv_heads, cfg.head_dim_
        if kind == "attn":
            shape = (B, max_len, Hk, Dh)
            return {"k": jnp.zeros(shape, dtype),
                    "v": jnp.zeros(shape, dtype)}
        if kind == "attn_local":
            # rolling buffer is always window-sized (prefill fills
            # slot pos % window even when max_len < window)
            shape = (B, cfg.window, Hk, Dh)
            return {"k": jnp.zeros(shape, dtype),
                    "v": jnp.zeros(shape, dtype)}
        if kind == "mla":
            return {"ckv": jnp.zeros((B, max_len, cfg.kv_lora), dtype),
                    "kr": jnp.zeros((B, max_len, cfg.qk_rope_dim), dtype)}
        if kind == "mamba":
            return {"conv": jnp.zeros((B, cfg.ssm_conv - 1,
                                       cfg.ssm_d_inner), dtype),
                    "h": jnp.zeros((B, cfg.ssm_d_inner, cfg.ssm_state),
                                   jnp.float32)}
        if kind == "rglru":
            k = cfg.ssm_conv or 4
            return {"conv": jnp.zeros((B, k - 1, cfg.lru_width_), dtype),
                    "h": jnp.zeros((B, cfg.lru_width_), jnp.float32)}
        raise ValueError(kind)

    stack = lambda kind: jax.tree.map(
        lambda z: jnp.broadcast_to(z[None], (n_body,) + z.shape), one(kind))
    return {"head": [one(k) for k in head],
            "body": {f"pos{i}": stack(k) for i, k in enumerate(pattern)},
            "tail": [one(k) for k in tail]}


def apply_decoder(cfg: ArchConfig, params: dict, x: Array, positions: Array,
                  mode: str, cache: Optional[dict] = None,
                  cache_index: Array | int = 0,
                  mla_absorbed: bool = False):
    """Returns (hidden (B,S,d), new_cache, aux_loss_sum)."""
    head, n_body, pattern, tail = _layer_plan(cfg)
    use_moe = _uses_moe(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {"head": [], "body": {}, "tail": []}

    blk = functools.partial(apply_block, cfg)
    # head layers: dense FFN even in MoE configs (DeepSeek lead-in)
    for i, kind in enumerate(head):
        c = cache["head"][i] if cache is not None else None
        x, nc, aux = blk(kind, False, params["head"][i], x, positions, mode,
                         c, cache_index, mla_absorbed)
        aux_total += aux
        new_cache["head"].append(nc)

    # body: one scan over the stacked pattern repeats
    if n_body:
        body_params = tuple(params["body"][f"pos{i}"]
                            for i in range(len(pattern)))

        def step(x, xs):
            if cache is not None:
                p_slices, c_slices = xs
            else:
                p_slices, c_slices = xs, (None,) * len(pattern)
            aux_step = jnp.zeros((), jnp.float32)
            ncs = []
            for pos, kind in enumerate(pattern):
                x, nc, aux = blk(kind, use_moe, p_slices[pos], x, positions,
                                 mode, c_slices[pos], cache_index,
                                 mla_absorbed)
                aux_step += aux
                ncs.append(nc)
            if mode == "train":
                return x, aux_step
            return x, (tuple(ncs), aux_step)

        if cfg.remat and mode == "train":
            step = jax.checkpoint(step, prevent_cse=False)

        unroll = n_body if cfg.unroll_layers else cfg.scan_unroll
        if mode == "train":
            x, auxs = jax.lax.scan(step, x, body_params, unroll=unroll)
        elif mode == "prefill":
            x, (nc_body, auxs) = jax.lax.scan(step, x, body_params,
                                              unroll=unroll)
            new_cache["body"] = {f"pos{i}": nc_body[i]
                                 for i in range(len(pattern))}
        else:  # decode
            body_cache = tuple(cache["body"][f"pos{i}"]
                               for i in range(len(pattern)))
            x, (nc_body, auxs) = jax.lax.scan(step, x,
                                              (body_params, body_cache),
                                              unroll=unroll)
            new_cache["body"] = {f"pos{i}": nc_body[i]
                                 for i in range(len(pattern))}
        aux_total += jnp.sum(auxs)

    for i, kind in enumerate(tail):
        c = cache["tail"][i] if cache is not None else None
        x, nc, aux = blk(kind, use_moe, params["tail"][i], x, positions,
                         mode, c, cache_index, mla_absorbed)
        aux_total += aux
        new_cache["tail"].append(nc)

    x = rmsnorm(x, params["final_norm"])
    return x, (new_cache if cache is not None or mode == "prefill"
               else None), aux_total
