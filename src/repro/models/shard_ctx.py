"""Activation-sharding context.

Models are mesh-agnostic; the launcher installs a constrainer that maps
logical activation names -> jax.lax.with_sharding_constraint with the
production mesh.  Default is identity (single-device tests).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable

import jax

Array = jax.Array

_constrainer: contextvars.ContextVar[Callable[[Array, str], Array]] = \
    contextvars.ContextVar("constrainer", default=lambda x, name: x)


def constrain(x: Array, name: str) -> Array:
    """Apply the active sharding constraint for logical name ``name``.

    Names used by the zoo: "act_btd" (batch, seq, d_model),
    "act_btf" (ffn hidden), "act_bthd" (per-head), "logits_btv",
    "kv_cache", "moe_ecd" (expert, capacity, d).
    """
    return _constrainer.get()(x, name)


@contextlib.contextmanager
def use_constrainer(fn: Callable[[Array, str], Array]):
    token = _constrainer.set(fn)
    try:
        yield
    finally:
        _constrainer.reset(token)
