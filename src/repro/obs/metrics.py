"""Process-wide metrics registry (counters, gauges, histograms).

Pure Python, no dependencies: a ``Registry`` holds metric *families*
(one per name); each family holds one value per label combination.
Three instrument types exist, mirroring the Prometheus data model:

* ``Counter`` — monotonically increasing float (``inc``);
* ``Gauge`` — last-write-wins float (``set``);
* ``Histogram`` — fixed upper-bound buckets plus ``sum``/``count``
  (``observe``).  Buckets are chosen at creation and never resized,
  so two snapshots of the same registry are always comparable.

The registry follows the same null-object pattern as the telemetry
sinks (``repro.obs.trace``): the process default is a ``NullRegistry``
whose instruments are shared no-ops, so instrumented solver code costs
a dict lookup *only when a real registry is installed* and nothing
perturbs numerics either way.  Install one with::

    from repro.obs import metrics

    reg = metrics.Registry()
    metrics.set_default(reg)
    ...run rounds...
    print(reg.render())           # Prometheus text exposition

Snapshots (``Registry.snapshot()``) are plain JSON and flow through
``Telemetry.emit`` as ``MetricsEvent`` records (schema v2), so a JSONL
trace doubles as a metrics archive::

    python -m repro.obs.metrics trace.jsonl   # exposition of the last
                                              # snapshot in the trace

Counters are cumulative, so the last snapshot carries the whole run.
"""
from __future__ import annotations

import re
from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from . import events as ev

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")

#: default histogram buckets, in seconds (Prometheus' defaults minus
#: the sub-millisecond tail the round loop never hits).
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape(value: str) -> str:
    return (value.replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt_labels(key: _LabelKey, extra: Sequence[Tuple[str, str]] = ()
                ) -> str:
    pairs = list(key) + list(extra)
    if not pairs:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class _NullInstrument:
    """Shared no-op counter/gauge/histogram."""

    __slots__ = ()

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        pass

    def set(self, value: float, **labels: Any) -> None:
        pass

    def observe(self, value: float, **labels: Any) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


class Counter:
    """Monotonic counter family; one value per label combination."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: Dict[_LabelKey, float] = OrderedDict()

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: Any) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def samples(self) -> List[Dict[str, Any]]:
        return [{"labels": dict(k), "value": v}
                for k, v in self._values.items()]

    def render_into(self, lines: List[str]) -> None:
        for key, v in self._values.items():
            lines.append(f"{self.name}{_fmt_labels(key)} {_fmt_value(v)}")


class Gauge(Counter):
    """Last-write-wins gauge family."""

    kind = "gauge"

    def set(self, value: float, **labels: Any) -> None:
        self._values[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount


class Histogram:
    """Fixed-bucket histogram family (cumulative ``le`` exposition)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError("histogram buckets must be sorted, non-empty")
        self.name = name
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)
        # per label-key: [per-bucket counts + overflow], sum, count
        self._counts: Dict[_LabelKey, List[int]] = OrderedDict()
        self._sums: Dict[_LabelKey, float] = {}
        self._totals: Dict[_LabelKey, int] = {}

    def observe(self, value: float, **labels: Any) -> None:
        key = _label_key(labels)
        counts = self._counts.get(key)
        if counts is None:
            counts = self._counts[key] = [0] * (len(self.buckets) + 1)
            self._sums[key] = 0.0
            self._totals[key] = 0
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
        self._sums[key] += float(value)
        self._totals[key] += 1

    def count(self, **labels: Any) -> int:
        return self._totals.get(_label_key(labels), 0)

    def sum(self, **labels: Any) -> float:
        return self._sums.get(_label_key(labels), 0.0)

    def quantile(self, q: float, **labels: Any) -> float:
        """Bucket-resolution quantile estimate (upper bound of the
        bucket containing the q-th observation; +Inf bucket returns the
        largest finite bound)."""
        key = _label_key(labels)
        counts = self._counts.get(key)
        total = self._totals.get(key, 0)
        if not counts or total == 0:
            return 0.0
        rank = q * total
        cum = 0
        for i, c in enumerate(counts):
            cum += c
            if cum >= rank and c:
                return (self.buckets[i] if i < len(self.buckets)
                        else self.buckets[-1])
        return self.buckets[-1]

    def samples(self) -> List[Dict[str, Any]]:
        return [{"labels": dict(k), "buckets": list(self._counts[k]),
                 "sum": self._sums[k], "count": self._totals[k]}
                for k in self._counts]

    def render_into(self, lines: List[str]) -> None:
        for key in self._counts:
            cum = 0
            for i, ub in enumerate(self.buckets):
                cum += self._counts[key][i]
                lines.append(f"{self.name}_bucket"
                             f"{_fmt_labels(key, [('le', repr(ub))])} "
                             f"{cum}")
            cum += self._counts[key][-1]
            lines.append(f"{self.name}_bucket"
                         f"{_fmt_labels(key, [('le', '+Inf')])} {cum}")
            lines.append(f"{self.name}_sum{_fmt_labels(key)} "
                         f"{_fmt_value(self._sums[key])}")
            lines.append(f"{self.name}_count{_fmt_labels(key)} "
                         f"{self._totals[key]}")


class NullRegistry:
    """Do-nothing registry; the interface contract for ``Registry``."""

    enabled: bool = False

    def counter(self, name: str, help: str = "") -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str, help: str = "") -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS
                  ) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def snapshot(self) -> List[Dict[str, Any]]:
        return []

    def snapshot_event(self, round: Optional[int] = None) -> ev.MetricsEvent:
        return ev.MetricsEvent(families=[], round=round)

    def render(self) -> str:
        return ""

    def reset(self) -> None:
        pass


#: shared no-op registry (the process default until one is installed).
NULL = NullRegistry()


class Registry(NullRegistry):
    """Recording registry: get-or-create metric families by name."""

    enabled = True

    def __init__(self):
        self._families: "OrderedDict[str, Any]" = OrderedDict()

    # -- instruments ---------------------------------------------------
    def _get(self, name: str, help: str, cls, **kw):
        fam = self._families.get(name)
        if fam is None:
            if not _NAME_RE.match(name):
                raise ValueError(f"invalid metric name: {name!r}")
            fam = self._families[name] = cls(name, help, **kw)
        elif not isinstance(fam, cls) or fam.kind != cls.kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{fam.kind}")
        return fam

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, help, Counter)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, help, Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, help, Histogram, buckets=buckets)

    # -- output --------------------------------------------------------
    def snapshot(self) -> List[Dict[str, Any]]:
        """JSON-safe dump: one dict per family, counters cumulative."""
        out = []
        for fam in self._families.values():
            rec: Dict[str, Any] = {"name": fam.name, "type": fam.kind,
                                   "help": fam.help,
                                   "samples": fam.samples()}
            if fam.kind == "histogram":
                rec["bucket_bounds"] = list(fam.buckets)
            out.append(rec)
        return out

    def snapshot_event(self, round: Optional[int] = None) -> ev.MetricsEvent:
        return ev.MetricsEvent(families=self.snapshot(), round=round)

    def render(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: List[str] = []
        for fam in self._families.values():
            if fam.help:
                lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            fam.render_into(lines)
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        self._families.clear()


def render_snapshot(families: Iterable[Dict[str, Any]]) -> str:
    """Rebuild a registry from ``Registry.snapshot()`` dicts (e.g. a
    trace's ``MetricsEvent.families``) and render its exposition."""
    reg = Registry()
    for fam in families:
        kind, name, help = fam["type"], fam["name"], fam.get("help", "")
        if kind == "counter":
            c = reg.counter(name, help)
            for s in fam["samples"]:
                c.inc(s["value"], **s.get("labels", {}))
        elif kind == "gauge":
            g = reg.gauge(name, help)
            for s in fam["samples"]:
                g.set(s["value"], **s.get("labels", {}))
        elif kind == "histogram":
            h = reg.histogram(name, help, buckets=fam["bucket_bounds"])
            for s in fam["samples"]:
                key = _label_key(s.get("labels", {}))
                h._counts[key] = list(s["buckets"])
                h._sums[key] = float(s["sum"])
                h._totals[key] = int(s["count"])
        else:
            raise ValueError(f"unknown metric type {kind!r} for {name!r}")
    return reg.render()


# ---------------------------------------------------------------------
# process-wide default registry (mirrors repro.obs.trace)
# ---------------------------------------------------------------------

_default: NullRegistry = NULL


def set_default(reg: Optional[NullRegistry]) -> None:
    """Install ``reg`` as the process default (``None`` resets)."""
    global _default
    _default = reg if reg is not None else NULL


def get_default() -> NullRegistry:
    return _default


def resolve(registry: Optional[NullRegistry]) -> NullRegistry:
    """``None`` -> the process default; anything else passes through."""
    return _default if registry is None else registry


def main(argv: Optional[List[str]] = None) -> None:
    """``python -m repro.obs.metrics trace.jsonl`` — render the last
    metrics snapshot in a trace as a Prometheus text exposition."""
    import argparse

    from . import summary as summary_mod

    ap = argparse.ArgumentParser(
        description="render a trace's metrics as Prometheus text")
    ap.add_argument("trace", help="JSONL trace file with metrics events")
    args = ap.parse_args(argv)
    last = None
    for rec in summary_mod.load_trace(args.trace):
        if rec.get("ev") == "metrics":
            last = rec
    if last is None:
        raise SystemExit(f"no metrics events in {args.trace}")
    print(render_snapshot(last["families"]), end="")


if __name__ == "__main__":
    main()
