"""Self-contained HTML dashboard for one telemetry trace.

    PYTHONPATH=src python -m repro.obs dash trace.jsonl -o report.html

One file, zero dependencies, zero external resources: every chart is
inline SVG, the palette lives in a ``<style>`` block (light + dark via
``prefers-color-scheme``), and hover detail rides on native SVG
``<title>`` tooltips.  Sections, in order:

* stat tiles — rounds, wall-clock, final cumulative net cost (eq. 18),
  fault and fallback counts;
* **round timeline** — per-round stacked stage seconds (the eq. 8/16
  latency story: where each round's wall-clock went), with fault
  markers overlaid on the rounds they hit;
* **per-device energy** — E^cmp (eq. 9) + E^com (eq. 16) stacked per
  device, summed over the trace (the eq. 17/18 cost attribution);
* **convergence-bound gap** — the ``feel_monitor_bound_gap_ratio``
  gauge per round from the trace's metrics snapshots (≈1 means the run
  tracks Lemma 2), when a monitor was attached;
* **fault table** — counts by kind, injected vs observed.

Charts follow the repro dataviz conventions: categorical stage hues in
fixed slot order (extra stages fold into "other"), red reserved for
fault status, text in ink tokens rather than series colors.
"""
from __future__ import annotations

import html
from typing import Any, Dict, Iterable, List, Optional, Tuple

from . import events as ev

# validated categorical palette (repro dataviz reference instance);
# slot order is the CVD-safety mechanism — never cycle past the list.
_SERIES_LIGHT = ("#2a78d6", "#eb6834", "#1baf7a", "#eda100", "#e87ba4",
                 "#008300", "#4a3aa7")
_SERIES_DARK = ("#3987e5", "#d95926", "#199e70", "#c98500", "#d55181",
                "#008300", "#9085e9")
_OTHER = "var(--muted)"
#: status red, reserved for fault markers — never a stage series.
_FAULT = "var(--status-critical)"

_CSS = """
:root { color-scheme: light dark; }
body {
  margin: 0; padding: 24px; font: 14px/1.45 system-ui, sans-serif;
  background: var(--surface-1); color: var(--text-primary);
  --surface-1: #fcfcfb; --surface-2: #f0efec;
  --text-primary: #0b0b0b; --text-secondary: #52514e;
  --muted: #a8a69e; --grid: #e3e2dd; --status-critical: #e34948;
}
@media (prefers-color-scheme: dark) {
  body {
    --surface-1: #1a1a19; --surface-2: #262625;
    --text-primary: #ffffff; --text-secondary: #c3c2b7;
    --muted: #6e6d66; --grid: #33332f; --status-critical: #e66767;
  }
  .light-only { display: none; }
}
@media (prefers-color-scheme: light) { .dark-only { display: none; } }
h1 { font-size: 20px; margin: 0 0 4px; }
h2 { font-size: 15px; margin: 28px 0 8px; }
.sub { color: var(--text-secondary); margin: 0 0 18px; }
.tiles { display: flex; gap: 12px; flex-wrap: wrap; margin: 18px 0; }
.tile {
  background: var(--surface-2); border-radius: 8px;
  padding: 10px 16px; min-width: 110px;
}
.tile .v { font-size: 22px; font-weight: 600; }
.tile .l { color: var(--text-secondary); font-size: 12px; }
.legend { display: flex; gap: 14px; flex-wrap: wrap;
          color: var(--text-secondary); font-size: 12px;
          margin: 6px 0 2px; }
.legend span { display: inline-flex; align-items: center; gap: 5px; }
.sw { width: 10px; height: 10px; border-radius: 3px;
      display: inline-block; }
table { border-collapse: collapse; font-size: 13px; }
td, th { padding: 4px 12px 4px 0; text-align: left;
         border-bottom: 1px solid var(--grid); }
th { color: var(--text-secondary); font-weight: 500; }
svg text { fill: var(--text-secondary); font: 11px system-ui; }
.note { color: var(--text-secondary); font-size: 12px; }
"""


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) >= 1000 or abs(v) < 0.01:
        return f"{v:.2e}"
    return f"{v:.3g}"


def _series_css(i: int) -> str:
    return f"var(--series-{i + 1})"


def _series_vars() -> str:
    light = "".join(f"--series-{i + 1}: {c}; "
                    for i, c in enumerate(_SERIES_LIGHT))
    dark = "".join(f"--series-{i + 1}: {c}; "
                   for i, c in enumerate(_SERIES_DARK))
    return (f"body {{ {light}}}\n"
            f"@media (prefers-color-scheme: dark) {{ body {{ {dark}}} }}\n")


# ---------------------------------------------------------------------
# data extraction
# ---------------------------------------------------------------------

def _records(trace: Iterable[Any]) -> List[Dict[str, Any]]:
    return [r.to_record() if hasattr(r, "to_record") else r for r in trace]


def _collect(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    stages: Dict[int, Dict[str, float]] = {}
    rounds: Dict[int, ev.RoundEvent] = {}
    device_cmp: List[float] = []
    device_com: List[float] = []
    faults: Dict[int, List[ev.FaultEvent]] = {}
    fault_totals: Dict[str, List[int]] = {}
    gap_by_round: Dict[int, float] = {}
    meta: Dict[str, Any] = {}
    for r in records:
        if r.get("ev") == "header":
            meta = r.get("meta", {})
            continue
        e = ev.parse_record(r)
        if isinstance(e, ev.StageEvent) and e.round is not None:
            per = stages.setdefault(e.round, {})
            per[e.stage] = per.get(e.stage, 0.0) + e.dur_s
        elif isinstance(e, ev.RoundEvent):
            rounds[e.round] = e
        elif isinstance(e, ev.DeviceEvent):
            k = len(e.energy_cmp_j)
            if len(device_cmp) < k:
                device_cmp.extend([0.0] * (k - len(device_cmp)))
                device_com.extend([0.0] * (k - len(device_com)))
            for i in range(k):
                device_cmp[i] += e.energy_cmp_j[i]
                device_com[i] += e.energy_com_j[i]
        elif isinstance(e, ev.FaultEvent):
            if e.round is not None:
                faults.setdefault(e.round, []).append(e)
            tot = fault_totals.setdefault(e.kind, [0, 0])
            tot[0] += 1
            tot[1] += int(bool(e.injected))
        elif isinstance(e, ev.MetricsEvent) and e.round is not None:
            for fam in e.families:
                if fam.get("name") == "feel_monitor_bound_gap_ratio":
                    for s in fam.get("samples", []):
                        gap_by_round[e.round] = float(s["value"])
    return {"stages": stages, "rounds": rounds,
            "device_cmp": device_cmp, "device_com": device_com,
            "faults": faults, "fault_totals": fault_totals,
            "gap": gap_by_round, "meta": meta}


# ---------------------------------------------------------------------
# SVG builders
# ---------------------------------------------------------------------

def _stacked_rounds_svg(stages: Dict[int, Dict[str, float]],
                        faults: Dict[int, List[ev.FaultEvent]],
                        order: List[str]) -> str:
    rounds = sorted(stages)
    if not rounds:
        return "<p class='note'>no stage events in this trace</p>"
    w, h, left, bottom, top = 720, 220, 46, 24, 14
    plot_w, plot_h = w - left - 10, h - bottom - top
    max_s = max(sum(stages[r].values()) for r in rounds) or 1.0
    bar_w = min(40.0, plot_w / max(len(rounds), 1) * 0.72)
    step = plot_w / max(len(rounds), 1)
    parts = [f"<svg viewBox='0 0 {w} {h}' role='img' "
             f"aria-label='stacked stage seconds per round'>"]
    # y grid: 4 recessive lines + labels
    for i in range(5):
        y = top + plot_h * (1 - i / 4)
        val = max_s * i / 4
        parts.append(f"<line x1='{left}' y1='{y:.1f}' x2='{w - 10}' "
                     f"y2='{y:.1f}' stroke='var(--grid)' "
                     f"stroke-width='1'/>")
        parts.append(f"<text x='{left - 6}' y='{y + 4:.1f}' "
                     f"text-anchor='end'>{_fmt(val)}s</text>")
    fold = [s for s in order[len(_SERIES_LIGHT):]]
    for idx, rnd in enumerate(rounds):
        x = left + idx * step + (step - bar_w) / 2
        y = top + plot_h
        per = stages[rnd]
        segs: List[Tuple[str, float, str]] = []
        for i, name in enumerate(order[:len(_SERIES_LIGHT)]):
            if per.get(name):
                segs.append((name, per[name], _series_css(i)))
        other = sum(per.get(n, 0.0) for n in fold)
        if other > 0:
            segs.append(("other", other, _OTHER))
        for name, dur, color in segs:
            seg_h = dur / max_s * plot_h
            y -= seg_h
            title = html.escape(f"round {rnd} · {name}: {dur * 1e3:.2f}ms")
            parts.append(
                f"<rect x='{x:.1f}' y='{y:.1f}' width='{bar_w:.1f}' "
                f"height='{max(seg_h - 1, 0.5):.1f}' rx='1.5' "
                f"fill='{color}' stroke='var(--surface-1)' "
                f"stroke-width='1'><title>{title}</title></rect>")
        if rnd in faults:
            kinds = sorted({f.kind for f in faults[rnd]})
            title = html.escape(
                f"round {rnd} faults: "
                + ", ".join(f"{k}×{sum(1 for f in faults[rnd] if f.kind == k)}"
                            for k in kinds))
            cx = x + bar_w / 2
            parts.append(
                f"<path d='M {cx - 4:.1f} {y - 6:.1f} l 4 -7 l 4 7 z' "
                f"fill='{_FAULT}'><title>{title}</title></path>")
        if len(rounds) <= 30 or idx % max(len(rounds) // 15, 1) == 0:
            parts.append(f"<text x='{x + bar_w / 2:.1f}' y='{h - 8}' "
                         f"text-anchor='middle'>{rnd}</text>")
    parts.append(f"<line x1='{left}' y1='{top + plot_h}' x2='{w - 10}' "
                 f"y2='{top + plot_h}' stroke='var(--text-secondary)' "
                 f"stroke-width='1'/>")
    parts.append("</svg>")
    return "".join(parts)


def _device_energy_svg(cmp_j: List[float], com_j: List[float]) -> str:
    if not cmp_j:
        return "<p class='note'>no device events in this trace</p>"
    K = len(cmp_j)
    w, h, left, bottom, top = 720, 200, 56, 24, 10
    plot_w, plot_h = w - left - 10, h - bottom - top
    max_j = max(a + b for a, b in zip(cmp_j, com_j)) or 1.0
    step = plot_w / K
    bar_w = min(44.0, step * 0.72)
    parts = [f"<svg viewBox='0 0 {w} {h}' role='img' "
             f"aria-label='per-device energy'>"]
    for i in range(5):
        y = top + plot_h * (1 - i / 4)
        parts.append(f"<line x1='{left}' y1='{y:.1f}' x2='{w - 10}' "
                     f"y2='{y:.1f}' stroke='var(--grid)'/>")
        parts.append(f"<text x='{left - 6}' y='{y + 4:.1f}' "
                     f"text-anchor='end'>{_fmt(max_j * i / 4)}J</text>")
    for k in range(K):
        x = left + k * step + (step - bar_w) / 2
        y = top + plot_h
        for label, val, color in (("E^cmp (eq. 9)", cmp_j[k],
                                   _series_css(0)),
                                  ("E^com (eq. 16)", com_j[k],
                                   _series_css(1))):
            if val <= 0:
                continue
            seg_h = val / max_j * plot_h
            y -= seg_h
            title = html.escape(f"device {k} · {label}: {val:.3e} J")
            parts.append(
                f"<rect x='{x:.1f}' y='{y:.1f}' width='{bar_w:.1f}' "
                f"height='{max(seg_h - 1, 0.5):.1f}' rx='1.5' "
                f"fill='{color}' stroke='var(--surface-1)' "
                f"stroke-width='1'><title>{title}</title></rect>")
        parts.append(f"<text x='{x + bar_w / 2:.1f}' y='{h - 8}' "
                     f"text-anchor='middle'>{k}</text>")
    parts.append(f"<line x1='{left}' y1='{top + plot_h}' x2='{w - 10}' "
                 f"y2='{top + plot_h}' stroke='var(--text-secondary)'/>")
    parts.append("</svg>")
    return "".join(parts)


def _gap_svg(gap: Dict[int, float]) -> str:
    if not gap:
        return ("<p class='note'>no metrics snapshots with "
                "feel_monitor_bound_gap_ratio — run with a "
                "ConvergenceMonitor and a metrics registry to "
                "populate this chart</p>")
    rounds = sorted(gap)
    w, h, left, bottom, top = 720, 180, 46, 24, 10
    plot_w, plot_h = w - left - 10, h - bottom - top
    max_v = max(max(gap.values()), 1.25)
    step = plot_w / max(len(rounds) - 1, 1)
    parts = [f"<svg viewBox='0 0 {w} {h}' role='img' "
             f"aria-label='convergence bound gap ratio per round'>"]
    for i in range(5):
        y = top + plot_h * (1 - i / 4)
        parts.append(f"<line x1='{left}' y1='{y:.1f}' x2='{w - 10}' "
                     f"y2='{y:.1f}' stroke='var(--grid)'/>")
        parts.append(f"<text x='{left - 6}' y='{y + 4:.1f}' "
                     f"text-anchor='end'>{_fmt(max_v * i / 4)}</text>")
    # reference line at ratio 1.0 (Lemma-2 bound exactly tight)
    y1 = top + plot_h * (1 - 1.0 / max_v)
    parts.append(f"<line x1='{left}' y1='{y1:.1f}' x2='{w - 10}' "
                 f"y2='{y1:.1f}' stroke='var(--muted)' "
                 f"stroke-dasharray='4 3'/>")
    parts.append(f"<text x='{w - 12}' y='{y1 - 4:.1f}' "
                 f"text-anchor='end'>bound = 1</text>")
    pts = []
    for i, rnd in enumerate(rounds):
        x = left + i * step
        y = top + plot_h * (1 - gap[rnd] / max_v)
        pts.append(f"{x:.1f},{y:.1f}")
    parts.append(f"<polyline points='{' '.join(pts)}' fill='none' "
                 f"stroke='{_series_css(0)}' stroke-width='2'/>")
    for i, rnd in enumerate(rounds):
        x = left + i * step
        y = top + plot_h * (1 - gap[rnd] / max_v)
        title = html.escape(f"round {rnd}: gap ratio {gap[rnd]:.3f}")
        parts.append(f"<circle cx='{x:.1f}' cy='{y:.1f}' r='4' "
                     f"fill='{_series_css(0)}' "
                     f"stroke='var(--surface-1)' stroke-width='2'>"
                     f"<title>{title}</title></circle>")
        if len(rounds) <= 30 or i % max(len(rounds) // 15, 1) == 0:
            parts.append(f"<text x='{x:.1f}' y='{h - 8}' "
                         f"text-anchor='middle'>{rnd}</text>")
    parts.append("</svg>")
    return "".join(parts)


def _legend(entries: List[Tuple[str, str]]) -> str:
    return ("<div class='legend'>"
            + "".join(f"<span><i class='sw' style='background:{c}'></i>"
                      f"{html.escape(n)}</span>" for n, c in entries)
            + "</div>")


# ---------------------------------------------------------------------
# page assembly
# ---------------------------------------------------------------------

def render_dashboard(trace: Iterable[Any]) -> str:
    """Trace records (raw dicts or events) -> one HTML page string."""
    data = _collect(_records(trace))
    stages, rounds = data["stages"], data["rounds"]
    totals: Dict[str, float] = {}
    for per in stages.values():
        for name, dur in per.items():
            totals[name] = totals.get(name, 0.0) + dur
    canon = [s for s in ev.CANONICAL_STAGES if s in totals]
    extra = sorted((s for s in totals if s not in ev.CANONICAL_STAGES),
                   key=lambda s: -totals[s])
    order = canon + extra

    n_rounds = len(rounds)
    wall = sum(r.wall_s for r in rounds.values())
    cum_cost = sum(r.net_cost for r in rounds.values())
    n_faults = sum(v[0] for v in data["fault_totals"].values())
    n_fallbacks = data["fault_totals"].get("fallback", [0, 0])[0]
    accs = [r.test_acc for r in sorted(rounds)
            for r in [rounds[r]] if r.test_acc is not None]
    final_acc = accs[-1] if accs else None

    meta = data["meta"]
    source = html.escape(str(meta.get("source", "unknown source")))

    tiles = [("rounds", str(n_rounds)),
             ("wall-clock", f"{wall:.2f}s"),
             ("cum. net cost", _fmt(cum_cost)),
             ("faults", str(n_faults)),
             ("fallbacks", str(n_fallbacks))]
    if final_acc is not None:
        tiles.append(("final acc", f"{final_acc:.3f}"))
    tiles_html = "".join(
        f"<div class='tile'><div class='v'>{html.escape(v)}</div>"
        f"<div class='l'>{html.escape(l)}</div></div>"
        for l, v in tiles)

    stage_legend = _legend(
        [(n, _series_css(i))
         for i, n in enumerate(order[:len(_SERIES_LIGHT)])]
        + ([("other", _OTHER)] if len(order) > len(_SERIES_LIGHT) else [])
        + ([("fault", _FAULT)] if data["faults"] else []))

    fault_rows = "".join(
        f"<tr><td>{html.escape(kind)}</td><td>{tot}</td>"
        f"<td>{inj}</td><td>{tot - inj}</td></tr>"
        for kind, (tot, inj) in sorted(data["fault_totals"].items(),
                                       key=lambda kv: -kv[1][0]))
    fault_table = (
        "<table><tr><th>kind</th><th>count</th><th>injected</th>"
        "<th>observed</th></tr>" + fault_rows + "</table>"
        if fault_rows else "<p class='note'>no fault events — a clean "
        "run, or the resilience layer was off</p>")

    return f"""<!doctype html>
<html lang="en"><head><meta charset="utf-8">
<meta name="viewport" content="width=device-width, initial-scale=1">
<title>FEEL round report — {source}</title>
<style>{_CSS}{_series_vars()}</style></head>
<body>
<h1>FEEL round report</h1>
<p class="sub">source: {source} · schema v{ev.SCHEMA_VERSION} reader ·
 generated by <code>python -m repro.obs dash</code></p>
<div class="tiles">{tiles_html}</div>

<h2>Round timeline — stacked stage seconds</h2>
<p class="sub">Where each round's wall-clock went (eq. 8/16 latency
 terms as measured). Red markers flag rounds with fault or fallback
 activity; hover any segment for exact timings.</p>
{stage_legend}
{_stacked_rounds_svg(stages, data["faults"], order)}

<h2>Per-device energy (eqs. 9 + 16)</h2>
<p class="sub">E^cmp + E^com summed over the trace — the per-device
 side of the eq. 17/18 cost the server is billed.</p>
{_legend([("E^cmp compute", _series_css(0)),
          ("E^com upload", _series_css(1))])}
{_device_energy_svg(data["device_cmp"], data["device_com"])}

<h2>Convergence-bound gap ratio</h2>
<p class="sub">Observed optimality-gap proxy / Lemma-2 predicted bound
 per round (&le; 1 means the run obeys the theory; see
 docs/telemetry.md).</p>
{_gap_svg(data["gap"])}

<h2>Faults and policy reactions</h2>
{fault_table}
</body></html>
"""


def write_dashboard(trace_path: str, out_path: str) -> str:
    from . import summary as summary_mod

    page = render_dashboard(summary_mod.load_trace(trace_path))
    with open(out_path, "w", encoding="utf-8") as f:
        f.write(page)
    return out_path


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs dash",
        description="render a JSONL trace as a self-contained HTML "
                    "round dashboard (inline SVG, no external assets)")
    ap.add_argument("trace", help="JSONL trace file")
    ap.add_argument("-o", "--out", default="report.html",
                    help="output HTML path (default report.html)")
    args = ap.parse_args(argv)
    out = write_dashboard(args.trace, args.out)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
