"""Kernel roofline profiling: FLOPs/bytes per jitted function.

``cost_of`` lowers + compiles a jitted function ahead-of-time and reads
XLA's ``cost_analysis()`` — HLO FLOPs and bytes accessed — normalizing
the per-device-list shape some jax versions return.  ``profile_jitted``
wraps that into a ``ProfileEvent`` (schema v2) recorded once per
(function, input shapes) compilation, stamped with the backend's
estimated peak FLOP/s so achieved-vs-peak utilization can be computed
later, on any machine, from the trace alone:

    utilization(stage) = flops / (stage seconds per call) / peak_flops

``repro.obs.summary`` joins profile events against stage timings to
surface exactly that (``telemetry.roofline.<stage>`` rows), and
``benchmarks/roofline.py --trace`` prints the same table standalone.

Peak FLOP/s is calibrated once per process by timing a dense f32
matmul (override with ``REPRO_PEAK_FLOPS=<float>`` for a known part —
e.g. a TPU v4 chip's 2.75e14 bf16 FLOP/s — or to pin CI numbers).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, Optional, Tuple

from . import events as ev
from . import metrics as metrics_mod
from . import trace as trace_mod

_PEAK_CACHE: Optional[float] = None


def peak_flops() -> float:
    """Estimated peak FLOP/s of the default backend (cached).

    Honors ``REPRO_PEAK_FLOPS``; otherwise times a 1024^3 f32 matmul
    (best of three) — a *practical* peak, which is the right
    denominator for "how much of what this machine can do did we use".
    """
    global _PEAK_CACHE
    if _PEAK_CACHE is not None:
        return _PEAK_CACHE
    env = os.environ.get("REPRO_PEAK_FLOPS")
    if env:
        _PEAK_CACHE = float(env)
        return _PEAK_CACHE
    import jax
    import jax.numpy as jnp

    n = 1024
    a = jnp.ones((n, n), jnp.float32)
    mm = jax.jit(lambda x: x @ x)
    mm(a).block_until_ready()  # compile + warm
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        mm(a).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    _PEAK_CACHE = 2.0 * n ** 3 / max(best, 1e-9)
    return _PEAK_CACHE


def cost_of(fn, *args) -> Dict[str, float]:
    """Lower + compile ``fn`` (a ``jax.jit`` callable) on ``args`` and
    return ``{"flops", "bytes_accessed", "compile_s"}`` from XLA's cost
    analysis.  jax < 0.4.34 returns one dict per device — take the
    first (SPMD: identical per device)."""
    t0 = time.perf_counter()
    compiled = fn.lower(*args).compile()
    compile_s = time.perf_counter() - t0
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "compile_s": compile_s}


@dataclasses.dataclass
class KernelProfile:
    """One profiled compilation (the in-memory face of ``ProfileEvent``)."""

    name: str
    stage: Optional[str]
    flops: float
    bytes_accessed: float
    peak_flops: float
    compile_s: float

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_accessed, 1.0)

    def utilization(self, wall_s_per_call: float) -> float:
        """Achieved / peak FLOP/s for one execution of this kernel."""
        if wall_s_per_call <= 0.0 or self.peak_flops <= 0.0:
            return 0.0
        return self.flops / wall_s_per_call / self.peak_flops


def profile_jitted(fn, args: Tuple[Any, ...], name: str,
                   stage: Optional[str] = None, telemetry=None,
                   registry=None,
                   round: Optional[int] = None) -> KernelProfile:
    """Profile one jitted function, emit the ``ProfileEvent`` and the
    ``feel_kernel_*`` gauges, and return the ``KernelProfile``."""
    cost = cost_of(fn, *args)
    prof = KernelProfile(name=name, stage=stage, flops=cost["flops"],
                         bytes_accessed=cost["bytes_accessed"],
                         peak_flops=peak_flops(),
                         compile_s=cost["compile_s"])
    tele = trace_mod.resolve(telemetry)
    tele.emit(ev.ProfileEvent(name=name, stage=stage, flops=prof.flops,
                              bytes_accessed=prof.bytes_accessed,
                              peak_flops=prof.peak_flops,
                              compile_s=prof.compile_s, round=round))
    reg = metrics_mod.resolve(registry)
    if reg.enabled:
        reg.gauge("feel_kernel_flops",
                  "HLO FLOPs per call of each jitted kernel").set(
                      prof.flops, kernel=name)
        reg.gauge("feel_kernel_bytes",
                  "HLO bytes accessed per call of each jitted kernel").set(
                      prof.bytes_accessed, kernel=name)
    return prof
