"""Versioned event schema for the round-level telemetry trace.

A trace is a JSONL file: one JSON object per line, each carrying an
``"ev"`` discriminator and a ``"v"`` schema version.  Ten event kinds
exist (see docs/telemetry.md for the field-by-field reference):

``header``   trace metadata, written once at the top of the file;
``stage``    one timed section of a round (the ``stage(...)`` context
             manager) — canonical names: ``data``, ``sigma``,
             ``matching``, ``power``, ``selection``, ``objective``,
             ``local_grads``, ``aggregate``, ``eval``;
``solver``   counters from one solver invocation (swap count, sweeps,
             CCP iterations, GP steps, feasibility);
``devices``  per-device arrays for one round: energy terms of
             eqs. (16)-(18), selected/uploaded counts, mislabel
             fraction among the selected samples;
``round``    the round roll-up: wall-clock, net cost (eq. 18),
             Delta_hat (eq. 26), feasibility.

Schema v2 adds (all three optional — v1 traces remain readable):

``metrics``  a snapshot of the process metrics registry
             (``repro.obs.metrics``): counters, gauges, histograms;
``monitor``  one structured warning from the convergence monitor
             (``repro.obs.monitor``): Lemma-2 bound violation, gap
             divergence, or straggler round/stage;
``profile``  per-jitted-function roofline numbers recorded once per
             compilation (``repro.obs.profile``): HLO FLOPs, bytes
             accessed, estimated peak FLOP/s.

Schema v3 adds (optional — v1/v2 traces remain readable):

``fault``    one fault-tolerance event (``repro.fed.faults`` and the
             resilience policies in ``repro.fed.rounds``): an injected
             or observed fault (dropout, straggler, NaN upload, solver
             failure) or the policy reaction to one (retry, fallback,
             quarantine, skipped update, checkpoint, resume).

Schema v4 adds hierarchical *span* tracing (v1-v3 traces remain
readable):

``span``     one timed section in the round's span tree
             (``Telemetry.span(name, **attrs)``): ``span_id`` /
             ``parent_id`` link spans into a tree rooted at the round
             span, ``attrs`` carries JSON-scalar context (device
             index, CCP iteration, sweep number, ...);
``stage``    records gain optional ``span_id``/``parent_id`` fields —
             a timed stage *is* a span (``stage()`` is an alias of
             ``span()``), so stages nest into the same tree while
             every v1-v3 consumer keeps reading them unchanged;
``fault``    records gain an optional ``t_s`` timestamp (seconds since
             trace creation, same clock as ``t0_s``) so faults can be
             placed as instant markers on an exported timeline.

Events deliberately serialize to *flat* dicts of JSON scalars/lists so
a trace can be consumed with nothing but ``json.loads`` per line.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 4

#: canonical stage names instrumented by the FEEL round loop; sinks
#: accept any string so callers may add their own sections.
CANONICAL_STAGES = ("data", "sigma", "matching", "power", "selection",
                    "objective", "local_grads", "aggregate", "eval")

#: the six stages every instrumented ``FEELTrainer.run_round`` emits.
REQUIRED_STAGES = ("sigma", "matching", "power", "selection",
                   "local_grads", "aggregate")


@dataclasses.dataclass
class StageEvent:
    """One timed section: ``dur_s`` seconds starting ``t0_s`` after
    trace creation (monotonic clock).

    Since schema v4 a stage is also a node in the span tree:
    ``span_id``/``parent_id`` (both None on pre-v4 records and on
    hand-built events) link it to its enclosing span.
    """

    stage: str
    t0_s: float
    dur_s: float
    round: Optional[int] = None
    span_id: Optional[int] = None
    parent_id: Optional[int] = None

    def to_record(self) -> Dict[str, Any]:
        rec = {"ev": "stage", "v": SCHEMA_VERSION, "round": self.round,
               "stage": self.stage, "t0_s": self.t0_s,
               "dur_s": self.dur_s}
        if self.span_id is not None:
            rec["span_id"] = self.span_id
            rec["parent_id"] = self.parent_id
        return rec


@dataclasses.dataclass
class SpanEvent:
    """One node of the hierarchical span tree (new in schema v4).

    ``span_id`` is unique within a trace; ``parent_id`` is the id of
    the enclosing span (None for a root span, e.g. the per-round
    ``round`` span).  ``attrs`` holds JSON scalars recorded at span
    entry (device index, CCP iteration, sweep number, solver method).
    Emitted at span *exit*, so a trace lists children before parents;
    ``repro.obs.spans.build_tree`` reconstructs the tree either way.
    """

    name: str
    span_id: int
    t0_s: float
    dur_s: float
    parent_id: Optional[int] = None
    round: Optional[int] = None
    attrs: Optional[Dict[str, Any]] = None

    def to_record(self) -> Dict[str, Any]:
        return {"ev": "span", "v": SCHEMA_VERSION, "round": self.round,
                "name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "t0_s": self.t0_s,
                "dur_s": self.dur_s, "attrs": dict(self.attrs or {})}


@dataclasses.dataclass
class SolverEvent:
    """Counters from one solver call.

    ``solver`` is ``matching`` (Alg. 2), ``power`` (Alg. 3 / closed
    form) or ``selection`` (Algs. 4-5 / exact oracle); ``counters``
    holds JSON scalars (ints, floats, bools, short strings).
    """

    solver: str
    counters: Dict[str, Any]
    round: Optional[int] = None

    def to_record(self) -> Dict[str, Any]:
        return {"ev": "solver", "v": SCHEMA_VERSION, "round": self.round,
                "solver": self.solver, "counters": dict(self.counters)}


@dataclasses.dataclass
class DeviceEvent:
    """Per-device accounting for one round; every list has length K.

    ``energy_cmp_j`` is E^cmp_k (eq. 9), ``energy_com_j`` is E^com_k
    (below eq. 16), ``cost`` is c_k (E^cmp_k + E^com_k) (eqs. 10+17),
    ``reward`` is q_k |M_k| (eq. 7) — net cost (eq. 18) is
    sum(cost) - sum(reward).
    """

    round: int
    energy_cmp_j: List[float]
    energy_com_j: List[float]
    cost: List[float]
    reward: List[float]
    selected: List[int]
    uploaded: List[int]
    mislabel_frac: List[float]

    def to_record(self) -> Dict[str, Any]:
        return {"ev": "devices", "v": SCHEMA_VERSION, "round": self.round,
                "energy_cmp_j": self.energy_cmp_j,
                "energy_com_j": self.energy_com_j,
                "cost": self.cost, "reward": self.reward,
                "selected": self.selected, "uploaded": self.uploaded,
                "mislabel_frac": self.mislabel_frac}


@dataclasses.dataclass
class RoundEvent:
    """Round roll-up; ``wall_s`` covers the whole ``run_round`` call."""

    round: int
    wall_s: float
    net_cost: float
    delta_obj: float
    n_selected: int
    n_uploaded: int
    feasible: bool
    test_acc: Optional[float] = None

    def to_record(self) -> Dict[str, Any]:
        return {"ev": "round", "v": SCHEMA_VERSION, "round": self.round,
                "wall_s": self.wall_s, "net_cost": self.net_cost,
                "delta_obj": self.delta_obj,
                "n_selected": self.n_selected,
                "n_uploaded": self.n_uploaded, "feasible": self.feasible,
                "test_acc": self.test_acc}


@dataclasses.dataclass
class MetricsEvent:
    """Snapshot of a metrics registry (new in schema v2).

    ``families`` is the list produced by ``Registry.snapshot()``: one
    dict per metric family with ``name``, ``type``, ``help`` and
    ``samples`` (plus ``bucket_bounds`` for histograms).  Counters are
    cumulative, so the *last* metrics event in a trace carries the
    whole run's totals.
    """

    families: List[Dict[str, Any]]
    round: Optional[int] = None

    def to_record(self) -> Dict[str, Any]:
        return {"ev": "metrics", "v": SCHEMA_VERSION, "round": self.round,
                "families": list(self.families)}


@dataclasses.dataclass
class MonitorEvent:
    """One structured convergence-monitor warning (new in schema v2).

    ``kind`` is ``bound_violation`` (observed gap exceeded the Lemma-2
    one-round bound), ``gap_divergence`` (gap increased monotonically
    over the monitor's window) or ``straggler`` (round or stage wall
    time exceeded k x the running median).  ``value`` is the observed
    quantity, ``threshold`` what it was checked against.
    """

    kind: str
    value: float
    threshold: float
    round: Optional[int] = None
    detail: Optional[Dict[str, Any]] = None

    def to_record(self) -> Dict[str, Any]:
        return {"ev": "monitor", "v": SCHEMA_VERSION, "round": self.round,
                "kind": self.kind, "value": self.value,
                "threshold": self.threshold,
                "detail": dict(self.detail or {})}


@dataclasses.dataclass
class ProfileEvent:
    """Roofline numbers for one jitted function (new in schema v2).

    Recorded once per (function, input shapes) compilation.  ``flops``
    and ``bytes_accessed`` come from XLA ``cost_analysis()``;
    ``peak_flops`` is the backend peak estimated *at trace time* so a
    trace stays interpretable on another machine.  ``stage`` links the
    profile to the stage events that time this function's executions.
    """

    name: str
    stage: Optional[str]
    flops: float
    bytes_accessed: float
    peak_flops: float
    compile_s: float = 0.0
    round: Optional[int] = None

    def to_record(self) -> Dict[str, Any]:
        return {"ev": "profile", "v": SCHEMA_VERSION, "round": self.round,
                "name": self.name, "stage": self.stage,
                "flops": self.flops,
                "bytes_accessed": self.bytes_accessed,
                "peak_flops": self.peak_flops,
                "compile_s": self.compile_s}


#: valid ``FaultEvent.kind`` values (see docs/robustness.md).
FAULT_KINDS = ("dropout", "straggler", "nan_upload", "solver_fail",
               "retry", "fallback", "quarantine", "skip_update",
               "partial_matching", "checkpoint", "resume")


@dataclasses.dataclass
class FaultEvent:
    """One fault or fault-tolerance reaction (new in schema v3).

    ``kind`` is one of ``FAULT_KINDS``; ``injected`` is True when the
    event originates from a ``repro.fed.faults.FaultPlan`` (chaos
    testing) and False when it was observed/defensive (a naturally
    infeasible solve, a real NaN, a policy reaction).  ``device`` is
    the device index for per-device faults, None for round/solver-level
    events.  ``detail`` holds JSON scalars (solver names, delays,
    attempt counts, strike counts, checkpoint paths).  ``t_s`` (new in
    schema v4, None on older records) is the emission time in seconds
    since trace creation — the same clock as ``StageEvent.t0_s`` — so
    exporters can place the fault as an instant marker on a timeline.
    """

    kind: str
    injected: bool
    round: Optional[int] = None
    device: Optional[int] = None
    detail: Optional[Dict[str, Any]] = None
    t_s: Optional[float] = None

    def to_record(self) -> Dict[str, Any]:
        rec = {"ev": "fault", "v": SCHEMA_VERSION, "round": self.round,
               "kind": self.kind, "injected": self.injected,
               "device": self.device, "detail": dict(self.detail or {})}
        if self.t_s is not None:
            rec["t_s"] = self.t_s
        return rec


def header_record(meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    return {"ev": "header", "v": SCHEMA_VERSION, "meta": dict(meta or {})}


_KINDS = {
    "stage": lambda r: StageEvent(stage=r["stage"], t0_s=r["t0_s"],
                                  dur_s=r["dur_s"], round=r.get("round"),
                                  span_id=r.get("span_id"),
                                  parent_id=r.get("parent_id")),
    "span": lambda r: SpanEvent(
        name=r["name"], span_id=r["span_id"],
        parent_id=r.get("parent_id"), t0_s=r["t0_s"], dur_s=r["dur_s"],
        round=r.get("round"), attrs=r.get("attrs")),
    "solver": lambda r: SolverEvent(solver=r["solver"],
                                    counters=r["counters"],
                                    round=r.get("round")),
    "devices": lambda r: DeviceEvent(
        round=r["round"], energy_cmp_j=r["energy_cmp_j"],
        energy_com_j=r["energy_com_j"], cost=r["cost"],
        reward=r["reward"], selected=r["selected"],
        uploaded=r["uploaded"], mislabel_frac=r["mislabel_frac"]),
    "round": lambda r: RoundEvent(
        round=r["round"], wall_s=r["wall_s"], net_cost=r["net_cost"],
        delta_obj=r["delta_obj"], n_selected=r["n_selected"],
        n_uploaded=r["n_uploaded"], feasible=r["feasible"],
        test_acc=r.get("test_acc")),
    "metrics": lambda r: MetricsEvent(families=r["families"],
                                      round=r.get("round")),
    "monitor": lambda r: MonitorEvent(
        kind=r["kind"], value=r["value"], threshold=r["threshold"],
        round=r.get("round"), detail=r.get("detail")),
    "profile": lambda r: ProfileEvent(
        name=r["name"], stage=r.get("stage"), flops=r["flops"],
        bytes_accessed=r["bytes_accessed"],
        peak_flops=r.get("peak_flops", 0.0),
        compile_s=r.get("compile_s", 0.0), round=r.get("round")),
    "fault": lambda r: FaultEvent(
        kind=r["kind"], injected=r["injected"], round=r.get("round"),
        device=r.get("device"), detail=r.get("detail"),
        t_s=r.get("t_s")),
}


def parse_record(record: Dict[str, Any]):
    """Dict (one JSONL line) -> typed event; header/unknown -> None.

    Raises ``ValueError`` when the record's schema version is *newer*
    than this reader so we fail loudly instead of mis-aggregating a
    future trace format.  Older versions parse fine: v2 added the
    ``metrics``/``monitor``/``profile`` kinds, v3 added ``fault``, and
    v4 added ``span`` plus *optional* fields on ``stage``
    (``span_id``/``parent_id``) and ``fault`` (``t_s``) — no existing
    field changed meaning, so every v1-v3 record is also a valid v4
    record.
    """
    v = record.get("v", SCHEMA_VERSION)
    if v > SCHEMA_VERSION:
        raise ValueError(f"trace schema v{v} > reader v{SCHEMA_VERSION}")
    make = _KINDS.get(record.get("ev"))
    return make(record) if make else None
