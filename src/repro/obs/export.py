"""Export a telemetry trace as Chrome trace-event JSON.

    PYTHONPATH=src python -m repro.obs export trace.jsonl -o trace.json

The output loads in Perfetto (https://ui.perfetto.dev) or
chrome://tracing and shows each round as a nested timeline:

* every span/stage becomes a complete event (``"ph": "X"``) with its
  recorded monotonic start/duration (microseconds, as the format
  requires);
* spans carrying a ``device`` attribute land on that device's own
  track (``device 3``), everything else on the ``rounds`` track, so
  per-device work reads as parallel lanes under the round span;
* fault events (dropout, straggler, fallback, quarantine, ...) become
  instant markers (``"ph": "i"``) at their recorded ``t_s`` — pre-v4
  traces carry no fault timestamps, so there they are placed at the
  end of their round's span when one exists and skipped otherwise;
* per-round counters (net cost, selected/uploaded samples) become
  counter events (``"ph": "C"``) anchored at the round span's end,
  rendered by Perfetto as step charts above the timeline.

The exporter consumes raw records or live event objects and never
needs more than the standard library.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

from . import events as ev
from . import spans as spans_mod

#: synthetic process id for the single-process trace.
PID = 1
#: tid of the main (round-loop) track; device k maps to DEVICE_TID0+k.
MAIN_TID = 0
DEVICE_TID0 = 100


def _us(seconds: float) -> float:
    return seconds * 1e6


def _tid(node: spans_mod.SpanNode) -> int:
    dev = node.attrs.get("device")
    return MAIN_TID if dev is None else DEVICE_TID0 + int(dev)


def to_chrome_trace(trace: Iterable[Any],
                    meta: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
    """Build the Chrome trace-event JSON object for a trace."""
    records = [r.to_record() if hasattr(r, "to_record") else r
               for r in trace]
    roots, orphans = spans_mod.build_tree(records)
    events: List[Dict[str, Any]] = []
    tids = {MAIN_TID}

    # -- spans: complete events ----------------------------------------
    round_spans: Dict[int, spans_mod.SpanNode] = {}
    for root in roots + orphans:
        for node in root.walk():
            if node.name == "round" and node.round is not None:
                round_spans.setdefault(node.round, node)
            tid = _tid(node)
            tids.add(tid)
            args: Dict[str, Any] = dict(node.attrs)
            if node.round is not None:
                args.setdefault("round", node.round)
            events.append({"name": node.name, "cat": node.kind,
                           "ph": "X", "ts": _us(node.t0_s),
                           "dur": _us(node.dur_s), "pid": PID,
                           "tid": tid, "args": args})

    # -- faults: instant markers; rounds: counter series ---------------
    for r in records:
        e = ev.parse_record(r)
        if isinstance(e, ev.FaultEvent):
            t_s = e.t_s
            if t_s is None:  # pre-v4 record: anchor to the round span
                rs = round_spans.get(e.round) if e.round is not None \
                    else None
                if rs is None:
                    continue
                t_s = rs.end_s
            tid = (MAIN_TID if e.device is None
                   else DEVICE_TID0 + int(e.device))
            tids.add(tid)
            args = {"injected": e.injected, **(e.detail or {})}
            if e.round is not None:
                args["round"] = e.round
            events.append({"name": f"fault:{e.kind}", "cat": "fault",
                           "ph": "i", "ts": _us(t_s), "pid": PID,
                           "tid": tid, "s": "t", "args": args})
        elif isinstance(e, ev.RoundEvent):
            rs = round_spans.get(e.round)
            if rs is None:
                continue
            ts = _us(rs.end_s)
            for name, value in (("net_cost", e.net_cost),
                                ("n_selected", e.n_selected),
                                ("n_uploaded", e.n_uploaded)):
                events.append({"name": name, "cat": "round", "ph": "C",
                               "ts": ts, "pid": PID, "tid": MAIN_TID,
                               "args": {"value": value}})

    # -- track naming metadata -----------------------------------------
    events.append({"name": "process_name", "ph": "M", "pid": PID,
                   "args": {"name": "FEEL round loop"}})
    for tid in sorted(tids):
        label = ("rounds" if tid == MAIN_TID
                 else f"device {tid - DEVICE_TID0}")
        events.append({"name": "thread_name", "ph": "M", "pid": PID,
                       "tid": tid, "args": {"name": label}})
        events.append({"name": "thread_sort_index", "ph": "M", "pid": PID,
                       "tid": tid, "args": {"sort_index": tid}})

    header = next((r for r in records if r.get("ev") == "header"), None)
    other = dict(meta or {})
    if header is not None:
        other.setdefault("trace_meta", header.get("meta", {}))
        other.setdefault("schema_version", header.get("v"))
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}


def export_file(trace_path: str, out_path: str) -> Dict[str, Any]:
    """Load a JSONL trace, convert, write ``out_path``; returns the
    trace object (handy for tests and callers wanting stats)."""
    from . import summary as summary_mod

    obj = to_chrome_trace(summary_mod.load_trace(trace_path))
    with open(out_path, "w", encoding="utf-8") as f:
        json.dump(obj, f)
    return obj


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs export",
        description="export a JSONL trace as Chrome trace-event JSON "
                    "(viewable in Perfetto / chrome://tracing)")
    ap.add_argument("trace", help="JSONL trace file")
    ap.add_argument("-o", "--out", default=None,
                    help="output path (default: <trace>.json)")
    args = ap.parse_args(argv)
    out = args.out or (args.trace.rsplit(".", 1)[0] + ".json")
    obj = export_file(args.trace, out)
    n_spans = sum(1 for e in obj["traceEvents"] if e.get("ph") == "X")
    n_faults = sum(1 for e in obj["traceEvents"] if e.get("ph") == "i")
    print(f"wrote {out}: {n_spans} spans, {n_faults} fault markers "
          f"({len(obj['traceEvents'])} events) — open in "
          f"https://ui.perfetto.dev or chrome://tracing")


if __name__ == "__main__":
    main()
