"""Roll a telemetry trace up into the ``name,us_per_call,derived`` CSV
shape that ``benchmarks/common.emit`` already prints.

    PYTHONPATH=src python -m repro.obs trace.jsonl

Aggregation rules
-----------------
* one ``telemetry.stage.<name>`` row per stage: mean us per call,
  ``derived`` carries call count, total seconds and the stage's share
  of total recorded round wall-clock;
* one ``telemetry.solver.<name>`` row per solver with summed/averaged
  counters (swaps, sweeps, CCP iterations, GP steps, infeasible calls);
* a ``telemetry.round`` row: mean round wall-clock, round count,
  infeasible-round count, and ``coverage`` = (sum of stage durations) /
  (sum of round wall-clock) — how much of each round the stages
  explain;
* a ``telemetry.device`` row: mean per-round totals of the eq. (16)-(18)
  energy/cost terms and selected/uploaded counts;
* one ``telemetry.roofline.<stage>`` row per profiled stage (schema v2
  ``profile`` events joined against that stage's mean wall-clock):
  HLO FLOPs/bytes per call, achieved GFLOP/s and achieved/peak
  utilization;
* a ``telemetry.monitor`` row when the convergence monitor raised any
  warnings: violation counts by kind;
* a ``telemetry.faults`` row when the trace carries any schema-v3
  ``fault`` events: counts by kind plus the injected-fault total.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Any, Dict, Iterable, List, Optional, Tuple

from . import events as ev


def load_trace(path: str, strict: bool = False) -> List[Dict[str, Any]]:
    """Read a JSONL trace into a list of record dicts (header included).

    A process that dies mid-``_write`` leaves a truncated final line;
    that is expected crash debris, so the default skips it with a
    warning (``strict=True`` restores the raise).  A malformed line
    anywhere *else* still raises — that is corruption, not truncation.
    """
    with open(path) as f:
        lines = f.readlines()
    out = []
    last = len(lines) - 1
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            if i == last and not strict:
                warnings.warn(f"{path}: skipping truncated final trace "
                              f"line ({line[:40]!r}...)")
                continue
            raise
    return out


def _records(trace: Iterable[Any]) -> List[Dict[str, Any]]:
    """Accept raw dicts (from JSONL) or event objects (from a live
    ``Telemetry.events`` list) interchangeably."""
    return [r.to_record() if hasattr(r, "to_record") else r for r in trace]


@dataclasses.dataclass
class StageStats:
    calls: int = 0
    total_s: float = 0.0

    @property
    def mean_us(self) -> float:
        return self.total_s / max(self.calls, 1) * 1e6


@dataclasses.dataclass
class TraceSummary:
    stages: Dict[str, StageStats]
    solvers: Dict[str, Dict[str, float]]   # solver -> aggregated counters
    n_rounds: int
    total_wall_s: float
    infeasible_rounds: int
    coverage: Optional[float]              # stage time / round wall time
    device_totals: Dict[str, float]        # mean per-round sums over k
    profiles: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)              # kernel name -> roofline record
    monitor_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)              # violation kind -> count
    fault_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)              # fault kind -> count (v3)
    faults_injected: int = 0               # of which FaultPlan-injected
    last_metrics: Optional[List[Dict[str, Any]]] = None  # last snapshot

    def stage_seconds(self) -> float:
        return sum(s.total_s for s in self.stages.values())

    def roofline(self) -> Dict[str, Dict[str, float]]:
        """Join profiles against stage timings: per profiled stage, the
        per-call FLOPs/bytes and achieved-vs-peak utilization."""
        out: Dict[str, Dict[str, float]] = {}
        for prof in self.profiles.values():
            stage = prof.get("stage")
            st = self.stages.get(stage) if stage else None
            if st is None or st.calls == 0 or st.total_s <= 0.0:
                continue
            per_call_s = st.total_s / st.calls
            achieved = prof["flops"] / per_call_s
            peak = prof.get("peak_flops") or 0.0
            out[stage] = {
                "kernel": prof["name"],
                "flops": prof["flops"],
                "bytes_accessed": prof["bytes_accessed"],
                "per_call_s": per_call_s,
                "achieved_flops_per_s": achieved,
                "peak_flops": peak,
                "utilization": achieved / peak if peak > 0 else 0.0,
            }
        return out


def summarize(trace: Iterable[Any]) -> TraceSummary:
    records = _records(trace)
    stages: Dict[str, StageStats] = {}
    solver_counts: Dict[str, Dict[str, float]] = {}
    solver_calls: Dict[str, int] = {}
    n_rounds = 0
    total_wall = 0.0
    infeasible = 0
    dev_totals: Dict[str, float] = {}
    dev_rounds = 0
    profiles: Dict[str, Dict[str, float]] = {}
    monitor_counts: Dict[str, int] = {}
    fault_counts: Dict[str, int] = {}
    faults_injected = 0
    last_metrics: Optional[List[Dict[str, Any]]] = None

    for r in records:
        e = ev.parse_record(r)
        if isinstance(e, ev.StageEvent):
            s = stages.setdefault(e.stage, StageStats())
            s.calls += 1
            s.total_s += e.dur_s
        elif isinstance(e, ev.SolverEvent):
            agg = solver_counts.setdefault(e.solver, {})
            solver_calls[e.solver] = solver_calls.get(e.solver, 0) + 1
            for k, v in e.counters.items():
                if k == "feasible":
                    # feasibility flags aggregate as a failure count
                    agg["infeasible"] = agg.get("infeasible", 0) + (not v)
                elif isinstance(v, (bool, int, float)):
                    agg[k] = agg.get(k, 0) + v
                else:
                    agg[k] = v  # strings (e.g. method=) keep last value
        elif isinstance(e, ev.RoundEvent):
            n_rounds += 1
            total_wall += e.wall_s
            if not e.feasible:
                infeasible += 1
        elif isinstance(e, ev.DeviceEvent):
            dev_rounds += 1
            for k in ("energy_cmp_j", "energy_com_j", "cost", "reward",
                      "selected", "uploaded"):
                dev_totals[k] = dev_totals.get(k, 0.0) + float(
                    sum(getattr(e, k)))
        elif isinstance(e, ev.ProfileEvent):
            profiles[e.name] = {"name": e.name, "stage": e.stage,
                                "flops": e.flops,
                                "bytes_accessed": e.bytes_accessed,
                                "peak_flops": e.peak_flops}
        elif isinstance(e, ev.MonitorEvent):
            monitor_counts[e.kind] = monitor_counts.get(e.kind, 0) + 1
        elif isinstance(e, ev.FaultEvent):
            fault_counts[e.kind] = fault_counts.get(e.kind, 0) + 1
            faults_injected += int(bool(e.injected))
        elif isinstance(e, ev.MetricsEvent):
            last_metrics = e.families  # counters are cumulative: last wins

    # normalize solver counters to per-call means where that reads better
    solvers: Dict[str, Dict[str, float]] = {}
    for name, agg in solver_counts.items():
        out = dict(agg)
        out["calls"] = solver_calls[name]
        solvers[name] = out

    coverage = None
    if total_wall > 0:
        stage_s = sum(s.total_s for s in stages.values())
        coverage = stage_s / total_wall

    if dev_rounds:
        dev_totals = {k: v / dev_rounds for k, v in dev_totals.items()}

    return TraceSummary(stages=stages, solvers=solvers, n_rounds=n_rounds,
                        total_wall_s=total_wall,
                        infeasible_rounds=infeasible, coverage=coverage,
                        device_totals=dev_totals, profiles=profiles,
                        monitor_counts=monitor_counts,
                        fault_counts=fault_counts,
                        faults_injected=faults_injected,
                        last_metrics=last_metrics)


def rows(summary: TraceSummary) -> List[Tuple[str, float, str]]:
    """CSV rows ``(name, us_per_call, derived)`` for ``common.emit``."""
    out: List[Tuple[str, float, str]] = []
    stage_s = summary.stage_seconds()
    for name in sorted(summary.stages,
                       key=lambda n: -summary.stages[n].total_s):
        s = summary.stages[name]
        share = s.total_s / stage_s if stage_s > 0 else 0.0
        out.append((f"telemetry.stage.{name}", s.mean_us,
                    f"calls={s.calls};total_s={s.total_s:.4f};"
                    f"share={share:.3f}"))
    for name in sorted(summary.solvers):
        agg = summary.solvers[name]
        calls = agg.get("calls", 0)
        parts = [f"{k}={agg[k]:g}" if isinstance(agg[k], (int, float))
                 else f"{k}={agg[k]}" for k in sorted(agg) if k != "calls"]
        out.append((f"telemetry.solver.{name}", 0.0,
                    f"calls={calls};" + ";".join(parts)))
    if summary.n_rounds:
        mean_us = summary.total_wall_s / summary.n_rounds * 1e6
        cov = ("" if summary.coverage is None
               else f";coverage={summary.coverage:.3f}")
        out.append(("telemetry.round", mean_us,
                    f"rounds={summary.n_rounds};"
                    f"infeasible={summary.infeasible_rounds}" + cov))
    if summary.device_totals:
        d = summary.device_totals
        out.append(("telemetry.device", 0.0,
                    f"energy_cmp_j={d.get('energy_cmp_j', 0):.3e};"
                    f"energy_com_j={d.get('energy_com_j', 0):.3e};"
                    f"cost={d.get('cost', 0):.4f};"
                    f"reward={d.get('reward', 0):.4f};"
                    f"selected={d.get('selected', 0):.1f};"
                    f"uploaded={d.get('uploaded', 0):.1f}"))
    for stage, r in sorted(summary.roofline().items()):
        out.append((f"telemetry.roofline.{stage}", r["per_call_s"] * 1e6,
                    f"kernel={r['kernel']};flops={r['flops']:.3e};"
                    f"bytes={r['bytes_accessed']:.3e};"
                    f"achieved_gflops={r['achieved_flops_per_s'] / 1e9:.2f};"
                    f"util={r['utilization']:.4f}"))
    if summary.monitor_counts:
        parts = ";".join(f"{k}={v}" for k, v in
                         sorted(summary.monitor_counts.items()))
        out.append(("telemetry.monitor", 0.0, parts))
    if summary.fault_counts:
        parts = ";".join(f"{k}={v}" for k, v in
                         sorted(summary.fault_counts.items()))
        out.append(("telemetry.faults", 0.0,
                    f"injected={summary.faults_injected};" + parts))
    return out


def emit(summary: TraceSummary, emit_fn=None) -> None:
    """Print the summary through ``benchmarks/common.emit`` (or any
    compatible ``(name, us, derived)`` printer)."""
    if emit_fn is None:
        def emit_fn(name, us, derived):
            print(f"{name},{us:.1f},{derived}")
    for name, us, derived in rows(summary):
        emit_fn(name, us, derived)


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL trace file")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    emit(summarize(load_trace(args.trace)))


if __name__ == "__main__":
    main()
