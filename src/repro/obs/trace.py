"""Telemetry sinks.

Two sinks share one interface:

* ``NullTelemetry`` — the process-wide default.  Every method is a
  cheap no-op (``stage`` hands back one shared, reusable null context
  manager), so instrumented code paths cost a single attribute lookup
  when telemetry is off and numerics are bit-for-bit unchanged.
* ``Telemetry`` — records events in memory and, when given a ``path``,
  streams them to a JSONL file line-by-line (partial traces survive a
  crash).  ``stage(name)`` times a ``with`` block on the monotonic
  clock; ``span(name, **attrs)`` (schema v4) does the same but nests —
  spans opened inside another span/stage record it as their parent, so
  the trace carries the round's full call tree (see
  ``repro.obs.spans``).  ``stage`` is the span variant that serializes
  as the legacy ``stage`` record and feeds ``feel_stage_seconds``.
  ``block`` calls ``jax.block_until_ready`` so device work is
  attributed to the stage that launched it rather than to whichever
  later stage happens to synchronize.

Sink resolution: instrumented entry points take ``telemetry=None`` and
call ``resolve`` — ``None`` means "use the process default" (set with
``set_default``, a ``NullTelemetry`` unless e.g. ``benchmarks/run.py
--trace`` installed a real sink).  Inner helpers that would flood the
trace (the swap-matching scorer's per-candidate power solves) pass the
``NULL`` sentinel explicitly to opt out.
"""
from __future__ import annotations

import atexit
import json
import time
import warnings
from typing import Any, Dict, IO, Optional

from . import events as ev
from . import metrics as metrics_mod


class _NullStage:
    """Shared reusable no-op context manager."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_STAGE = _NullStage()


class NullTelemetry:
    """Do-nothing sink; the interface contract for ``Telemetry``."""

    enabled: bool = False
    annotate: bool = False
    profile: bool = False

    def stage(self, name: str):
        return _NULL_STAGE

    def span(self, name: str, **attrs: Any):
        return _NULL_STAGE

    def block(self, x):
        return x

    def begin_round(self, i: int) -> None:
        pass

    def solver(self, solver: str, **counters: Any) -> None:
        pass

    def devices(self, **fields: Any) -> None:
        pass

    def round_end(self, **fields: Any) -> None:
        pass

    def fault(self, kind: str, injected: bool = False,
              device: Optional[int] = None, **detail: Any) -> None:
        pass

    def emit(self, event) -> None:
        pass

    def close(self) -> None:
        pass


#: explicit opt-out sentinel (see module docstring).
NULL = NullTelemetry()


class _Span:
    """Timed span context: allocates an id on entry, pushes itself on
    the sink's span stack (so nested spans know their parent), and
    emits one event on exit.  ``_TimedStage`` specializes the emitted
    event kind; everything else is shared."""

    __slots__ = ("_tele", "_name", "_attrs", "_t0", "span_id",
                 "parent_id")

    def __init__(self, tele: "Telemetry", name: str,
                 attrs: Optional[Dict[str, Any]] = None):
        self._tele = tele
        self._name = name
        self._attrs = attrs

    def __enter__(self):
        tele = self._tele
        self.span_id = tele._next_span_id()
        stack = tele._span_stack
        self.parent_id = stack[-1] if stack else None
        stack.append(self.span_id)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tele = self._tele
        stack = tele._span_stack
        # tolerate out-of-order exits (crash paths): pop down to self
        while stack and stack[-1] != self.span_id:
            stack.pop()
        if stack:
            stack.pop()
        self._emit(tele, self._t0 - tele.created_s, t1 - self._t0)
        return False

    def _emit(self, tele: "Telemetry", t0_s: float, dur: float) -> None:
        tele.emit(ev.SpanEvent(name=self._name, span_id=self.span_id,
                               parent_id=self.parent_id, t0_s=t0_s,
                               dur_s=dur, round=tele.current_round,
                               attrs=self._attrs))


class _TimedStage(_Span):
    """A stage is a span that serializes as the legacy ``stage`` record
    (plus the v4 span-id fields) and mirrors its duration into the
    ``feel_stage_seconds`` histogram — every v1-v3 consumer keeps
    working unchanged."""

    __slots__ = ()

    def _emit(self, tele: "Telemetry", t0_s: float, dur: float) -> None:
        tele.emit(ev.StageEvent(stage=self._name, t0_s=t0_s, dur_s=dur,
                                round=tele.current_round,
                                span_id=self.span_id,
                                parent_id=self.parent_id))
        reg = metrics_mod.get_default()
        if reg.enabled:
            reg.histogram("feel_stage_seconds",
                          "wall-clock per timed stage").observe(
                              dur, stage=self._name)


class Telemetry(NullTelemetry):
    """Recording sink (in-memory list + optional JSONL stream).

    Parameters
    ----------
    path:
        JSONL output file; ``None`` keeps events in memory only.
    annotate:
        ask ``FEELTrainer`` to wrap its jitted functions in
        ``jax.profiler`` trace annotations (visible in TensorBoard /
        Perfetto profiles; off by default — it renames traced
        computations, which can perturb compilation caching).
    profile:
        ask instrumented trainers to record one ``ProfileEvent``
        (HLO FLOPs / bytes, ``repro.obs.profile``) per jitted function
        and input-shape combination — costs one extra AOT compile per
        combination, so off by default.
    meta:
        free-form dict stored in the trace header.

    A file-backed sink registers an ``atexit`` close so traces survive
    un-context-managed use on exception paths; ``close()`` is
    idempotent and unregisters the hook.
    """

    enabled = True

    def __init__(self, path: Optional[str] = None, annotate: bool = False,
                 profile: bool = False,
                 meta: Optional[Dict[str, Any]] = None):
        self.annotate = annotate
        self.profile = profile
        self.created_s = time.perf_counter()
        self.current_round: Optional[int] = None
        self.events: list = []
        self.dropped_writes = 0
        self._span_stack: list = []
        self._span_seq = 0
        self._file: Optional[IO[str]] = None
        if path is not None:
            self._file = open(path, "w", encoding="utf-8")
            self._write(ev.header_record(meta))
            atexit.register(self.close)

    # -- recording -----------------------------------------------------
    def stage(self, name: str):
        return _TimedStage(self, name)

    def span(self, name: str, **attrs: Any):
        """Open a nested timed span; exits emit one ``SpanEvent``
        linked to the enclosing span (stage or span) via parent id."""
        return _Span(self, name, attrs or None)

    def _next_span_id(self) -> int:
        self._span_seq += 1
        return self._span_seq

    def block(self, x):
        import jax

        return jax.block_until_ready(x)

    def begin_round(self, i: int) -> None:
        self.current_round = i

    def solver(self, solver: str, **counters: Any) -> None:
        self.emit(ev.SolverEvent(solver=solver, counters=counters,
                                 round=self.current_round))

    def devices(self, **fields: Any) -> None:
        self.emit(ev.DeviceEvent(round=self.current_round, **fields))

    def round_end(self, **fields: Any) -> None:
        self.emit(ev.RoundEvent(round=self.current_round, **fields))

    def fault(self, kind: str, injected: bool = False,
              device: Optional[int] = None, **detail: Any) -> None:
        self.emit(ev.FaultEvent(kind=kind, injected=injected,
                                device=device, detail=detail,
                                round=self.current_round,
                                t_s=time.perf_counter() - self.created_s))

    def emit(self, event) -> None:
        self.events.append(event)
        if self._file is not None:
            self._write(event.to_record())

    # -- IO ------------------------------------------------------------
    def _write(self, record: Dict[str, Any]) -> None:
        """Append one JSONL record.  A closed or failing file must
        never crash training mid-round: the write is dropped, counted
        in ``dropped_writes``, and the sink keeps recording in memory
        (the first failure warns once and detaches the file)."""
        try:
            self._file.write(json.dumps(record) + "\n")
            self._file.flush()
        except (OSError, ValueError) as e:  # closed file raises ValueError
            self.dropped_writes += 1
            self._file = None
            warnings.warn(f"telemetry trace write failed "
                          f"({type(e).__name__}: {e}); further events "
                          f"stay in memory only")

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None
            try:
                atexit.unregister(self.close)
            except Exception:  # pragma: no cover - interpreter teardown
                pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# ---------------------------------------------------------------------
# process-wide default sink
# ---------------------------------------------------------------------

_default: NullTelemetry = NULL


def set_default(tele: Optional[NullTelemetry]) -> None:
    """Install ``tele`` as the process default (``None`` resets)."""
    global _default
    _default = tele if tele is not None else NULL


def get_default() -> NullTelemetry:
    return _default


def resolve(telemetry: Optional[NullTelemetry]) -> NullTelemetry:
    """``None`` -> the process default; anything else passes through."""
    return _default if telemetry is None else telemetry


def annotate_fn(fn, name: str):
    """Wrap ``fn`` in a ``jax.profiler`` trace annotation when the
    running jax exposes one; otherwise return ``fn`` unchanged."""
    try:
        import jax.profiler

        return jax.profiler.annotate_function(fn, name=name)
    except Exception:  # pragma: no cover - profiler API unavailable
        return fn
