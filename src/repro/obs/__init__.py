"""Round-level observability for the FEEL reproduction.

Where does a round's wall-clock and cost actually go — swap matching,
CCP power allocation, gradient-projection selection, the local
gradients themselves?  This package answers that with a versioned JSONL
trace (``events``), a sink with a zero-overhead no-op default
(``trace``) and an aggregator that rolls a trace into the benchmark CSV
format (``summary``).  See docs/telemetry.md.

Typical use::

    from repro import obs

    tele = obs.Telemetry(path="trace.jsonl")
    trainer = FEELTrainer(sys_, data, model, params, cfg, telemetry=tele)
    trainer.run(100)
    tele.close()
    obs.emit_summary(obs.summarize(tele.events))

or process-wide (what ``benchmarks/run.py --trace`` does)::

    obs.set_default(obs.Telemetry(path="trace.jsonl"))
"""
from . import events, summary, trace  # noqa: F401
from .events import (CANONICAL_STAGES, REQUIRED_STAGES,  # noqa: F401
                     SCHEMA_VERSION, DeviceEvent, RoundEvent, SolverEvent,
                     StageEvent, parse_record)
from .summary import load_trace, rows, summarize  # noqa: F401
from .summary import emit as emit_summary  # noqa: F401
from .trace import (NULL, NullTelemetry, Telemetry, annotate_fn,  # noqa: F401
                    get_default, resolve, set_default)

__all__ = [
    "SCHEMA_VERSION", "CANONICAL_STAGES", "REQUIRED_STAGES",
    "StageEvent", "SolverEvent", "DeviceEvent", "RoundEvent",
    "parse_record", "NullTelemetry", "Telemetry", "NULL",
    "set_default", "get_default", "resolve", "annotate_fn",
    "load_trace", "summarize", "rows", "emit_summary",
]
