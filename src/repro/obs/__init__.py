"""Round-level observability for the FEEL reproduction.

Where does a round's wall-clock and cost actually go — swap matching,
CCP power allocation, gradient-projection selection, the local
gradients themselves — and does the run still *obey the theory*?  This
package answers with four layers (see docs/telemetry.md):

* ``events``/``trace`` — a versioned JSONL trace and a sink with a
  zero-overhead no-op default;
* ``metrics`` — a process-wide counter/gauge/histogram registry with a
  Prometheus text exposition (``python -m repro.obs.metrics trace``);
* ``monitor`` — a ``ConvergenceMonitor`` checking observed optimality
  gaps against the paper's Lemma 2/3 bounds and flagging divergence
  and straggler rounds;
* ``profile`` — per-jitted-kernel FLOPs/bytes (roofline) recorded once
  per compilation, joined against stage wall-clock by ``summary``;
* ``spans``/``export``/``diff``/``dash`` (schema v4) — the hierarchical
  span tree over a trace plus its three consumers: Chrome/Perfetto
  trace-event export, base-vs-head delta attribution, and a
  self-contained HTML round dashboard (``python -m repro.obs
  export|diff|dash``).

Typical use::

    from repro import obs

    tele = obs.Telemetry(path="trace.jsonl")
    trainer = FEELTrainer(sys_, data, model, params, cfg, telemetry=tele)
    trainer.run(100)
    tele.close()
    obs.emit_summary(obs.summarize(tele.events))

or process-wide (what ``benchmarks/run.py --trace`` does)::

    obs.set_default(obs.Telemetry(path="trace.jsonl"))
    obs.metrics.set_default(obs.Registry())
"""
from . import (dash, diff, events, export, metrics,  # noqa: F401
               monitor, profile, spans, summary, trace)
from .dash import render_dashboard, write_dashboard  # noqa: F401
from .diff import TraceDiff, diff_traces  # noqa: F401
from .events import (CANONICAL_STAGES, FAULT_KINDS,  # noqa: F401
                     REQUIRED_STAGES, SCHEMA_VERSION, DeviceEvent,
                     FaultEvent, MetricsEvent, MonitorEvent, ProfileEvent,
                     RoundEvent, SolverEvent, SpanEvent, StageEvent,
                     parse_record)
from .export import export_file, to_chrome_trace  # noqa: F401
from .metrics import (NullRegistry, Registry,  # noqa: F401
                      render_snapshot)
from .monitor import (ConvergenceMonitor, MonitorConfig,  # noqa: F401
                      Violation)
from .profile import (KernelProfile, cost_of, peak_flops,  # noqa: F401
                      profile_jitted)
from .spans import (SpanNode, build_tree, iter_spans,  # noqa: F401
                    self_seconds_by_path)
from .summary import load_trace, rows, summarize  # noqa: F401
from .summary import emit as emit_summary  # noqa: F401
from .trace import (NULL, NullTelemetry, Telemetry, annotate_fn,  # noqa: F401
                    get_default, resolve, set_default)

__all__ = [
    "SCHEMA_VERSION", "CANONICAL_STAGES", "REQUIRED_STAGES",
    "FAULT_KINDS", "StageEvent", "SolverEvent", "DeviceEvent",
    "RoundEvent", "MetricsEvent", "MonitorEvent", "ProfileEvent",
    "FaultEvent", "SpanEvent",
    "parse_record", "NullTelemetry", "Telemetry", "NULL",
    "set_default", "get_default", "resolve", "annotate_fn",
    "NullRegistry", "Registry", "render_snapshot",
    "ConvergenceMonitor", "MonitorConfig", "Violation",
    "KernelProfile", "cost_of", "peak_flops", "profile_jitted",
    "load_trace", "summarize", "rows", "emit_summary",
    "SpanNode", "build_tree", "iter_spans", "self_seconds_by_path",
    "to_chrome_trace", "export_file", "TraceDiff", "diff_traces",
    "render_dashboard", "write_dashboard",
]
