"""Live convergence monitoring against the paper's analytical bounds.

The paper's contribution is an *analytical* handle on training
efficiency: Lemma 2 upper-bounds the next round's optimality gap from
this round's gap, gradient norm and data-selection Delta term; Lemma 3
chains those one-round bounds into a trajectory.  ``ConvergenceMonitor``
turns the bounds into runtime checks: feed it one observation per round
and it raises structured warnings — emitted as ``MonitorEvent``
telemetry records and ``feel_monitor_violations_total`` metrics — when

* ``bound_violation`` — the observed gap exceeds the Lemma-2 bound
  predicted from the *previous* round's observation (beyond a relative
  tolerance; the bound holds in expectation, so a single stochastic
  round may legitimately wiggle past it — tune ``bound_rtol``);
* ``gap_divergence`` — the gap increased monotonically over the last
  ``divergence_window`` rounds (training is going backwards);
* ``straggler`` — a round (or a stage, when stage timings are fed in)
  took more than ``straggler_factor`` x the running median.

The gap observation may be any consistent loss proxy: Lemma 2 is
invariant to the unknown L* offset (it appears identically on both
sides), so ``FEELTrainer`` feeds the mean training loss on the round's
batch.  ``eta`` should be the step size (exact for SGD; for Adam the
configured learning rate is a proxy and a larger ``bound_rtol`` is
appropriate).

Disabled is the default: ``FEELTrainer(..., monitor=None)`` skips every
monitor code path, keeping round outputs bit-for-bit identical.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Any, Dict, List, Optional

from ..core import convergence as conv_mod
from . import events as ev
from . import metrics as metrics_mod
from . import trace as trace_mod

#: MonitorEvent kinds, in the order the checks run.
VIOLATION_KINDS = ("bound_violation", "gap_divergence", "straggler")


@dataclasses.dataclass
class MonitorConfig:
    """Knobs for the three checks (see module docstring)."""

    beta: float = 1.0              # smoothness constant of Lemma 2
    mu: float = 0.0                # strong-convexity; >0 enables Lemma 3
    bound_rtol: float = 0.10       # slack on the one-round bound
    bound_atol: float = 1e-9
    divergence_window: int = 5     # consecutive increases => divergence
    straggler_factor: float = 3.0  # x median => straggler
    straggler_min_history: int = 5


@dataclasses.dataclass
class Violation:
    """One raised warning (also emitted as a ``MonitorEvent``)."""

    kind: str
    round: int
    value: float
    threshold: float
    detail: Dict[str, Any]


class ConvergenceMonitor:
    """Consumes per-round observations; raises structured warnings.

    Parameters
    ----------
    sys:
        the ``SystemParams`` whose ``D_hat_total`` scales the Lemma-2
        Delta term.
    config:
        a ``MonitorConfig``; ``None`` uses the defaults.
    telemetry:
        sink for ``MonitorEvent`` records; ``None`` resolves to the
        process default (no-op unless one is installed).
    registry:
        metrics registry for violation counters / bound-ratio gauges;
        ``None`` resolves to the process default.
    """

    def __init__(self, sys, config: Optional[MonitorConfig] = None,
                 telemetry=None, registry=None):
        self.sys = sys
        self.cfg = config or MonitorConfig()
        self._tele = trace_mod.resolve(telemetry)
        self._reg = metrics_mod.resolve(registry)
        self.violations: List[Violation] = []
        self.gaps: List[float] = []            # observed gap per round
        self.bounds: List[Optional[float]] = []  # Lemma-2 bound for that round
        self.multi_bounds: List[float] = []    # Lemma-3 trajectory (mu>0)
        self._next_bound: Optional[float] = None
        self._etas: List[float] = []
        self._deltas: List[float] = []
        self._walls: List[float] = []
        self._stage_hist: Dict[str, List[float]] = {}
        self._diverging = False

    # ------------------------------------------------------------------
    def observe_round(self, round: int, gap: float, g_norm_sq: float,
                      eta: float, delta_obj: float,
                      wall_s: Optional[float] = None,
                      stage_s: Optional[Dict[str, float]] = None
                      ) -> List[Violation]:
        """Feed one round's observations; returns new violations.

        ``gap``: loss proxy for L(w_i) - L* (offset-invariant);
        ``g_norm_sq``: ||g_hat_i||^2; ``eta``: step size;
        ``delta_obj``: the round decision's Delta term (eq. 26);
        ``wall_s``/``stage_s``: optional timings for straggler checks.
        """
        cfg = self.cfg
        out: List[Violation] = []

        # -- Lemma 2: gap vs the bound predicted last round -------------
        bound = self._next_bound
        self.gaps.append(float(gap))
        self.bounds.append(bound)
        if bound is not None:
            thr = bound + abs(bound) * cfg.bound_rtol + cfg.bound_atol
            if gap > thr:
                out.append(self._raise(
                    "bound_violation", round, float(gap), float(thr),
                    {"bound": float(bound), "rtol": cfg.bound_rtol}))
        self._next_bound = float(conv_mod.one_round_bound_from_delta(
            self.sys, gap, g_norm_sq, eta, cfg.beta, delta_obj))

        # -- Lemma 3 trajectory (optional) ------------------------------
        self._etas.append(float(eta))
        self._deltas.append(float(delta_obj))
        if cfg.mu > 0.0:
            self.multi_bounds.append(conv_mod.multi_round_bound(
                self.sys, self.gaps[0], cfg.mu, cfg.beta, self._etas,
                self._deltas))

        # -- divergence: monotone increase over the window --------------
        w = cfg.divergence_window
        if len(self.gaps) > w:
            tail = self.gaps[-(w + 1):]
            rising = all(b > a for a, b in zip(tail, tail[1:]))
            if rising and not self._diverging:
                out.append(self._raise(
                    "gap_divergence", round, float(gap), float(tail[0]),
                    {"window": w, "gap_start": float(tail[0])}))
            self._diverging = rising

        # -- stragglers -------------------------------------------------
        if wall_s is not None:
            v = self._straggler_check(round, "round", wall_s, self._walls)
            if v is not None:
                out.append(v)
            self._walls.append(float(wall_s))
        for stage, dur in (stage_s or {}).items():
            hist = self._stage_hist.setdefault(stage, [])
            v = self._straggler_check(round, stage, dur, hist)
            if v is not None:
                out.append(v)
            hist.append(float(dur))
        return out

    def _straggler_check(self, round: int, what: str, dur: float,
                         hist: List[float]) -> Optional[Violation]:
        cfg = self.cfg
        if len(hist) < cfg.straggler_min_history:
            return None
        med = statistics.median(hist)
        thr = cfg.straggler_factor * med
        if dur > thr:
            return self._raise("straggler", round, float(dur), float(thr),
                               {"what": what, "median_s": float(med),
                                "factor": cfg.straggler_factor})
        return None

    def _raise(self, kind: str, round: int, value: float, threshold: float,
               detail: Dict[str, Any]) -> Violation:
        v = Violation(kind=kind, round=round, value=value,
                      threshold=threshold, detail=detail)
        self.violations.append(v)
        self._tele.emit(ev.MonitorEvent(kind=kind, value=value,
                                        threshold=threshold, round=round,
                                        detail=detail))
        if self._reg.enabled:
            self._reg.counter(
                "feel_monitor_violations_total",
                "convergence-monitor warnings by kind").inc(1, kind=kind)
            if kind == "bound_violation":
                self._reg.gauge(
                    "feel_monitor_bound_gap_ratio",
                    "last observed gap / Lemma-2 bound").set(
                        value / threshold if threshold else float("inf"))
        return v

    # ------------------------------------------------------------------
    def bound_gap_ratio(self) -> Optional[float]:
        """max over rounds of observed gap / predicted Lemma-2 bound
        (<= 1 + rtol means the theory tracked reality); ``None`` until
        two rounds have been observed."""
        ratios = [g / b for g, b in zip(self.gaps, self.bounds)
                  if b is not None and b > 0.0]
        return max(ratios) if ratios else None

    def counts(self) -> Dict[str, int]:
        out = {k: 0 for k in VIOLATION_KINDS}
        for v in self.violations:
            out[v.kind] = out.get(v.kind, 0) + 1
        return out

    def summary(self) -> Dict[str, Any]:
        """JSON-safe roll-up (what ``benchmarks/regress.py`` records)."""
        return {"rounds": len(self.gaps),
                "violations": self.counts(),
                "bound_gap_ratio": self.bound_gap_ratio(),
                "final_gap": self.gaps[-1] if self.gaps else None,
                "final_bound": self._next_bound}
