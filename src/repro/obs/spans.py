"""Span-tree model over a telemetry trace (schema v4).

A v4 trace carries two record kinds with span identity: ``span``
records (``Telemetry.span(name, **attrs)``) and ``stage`` records
(``Telemetry.stage`` — a span that serializes in the legacy shape).
Both carry ``span_id``/``parent_id``; this module normalizes them into
one ``SpanNode`` shape and reconstructs the per-round call tree:

    round
    ├── data / sigma / matching / power / selection / ...   (stages)
    │     ├── matching.sweep(sweep=1)                       (spans)
    │     └── power.ccp_iter(iter=0..V)
    ├── local_grads / aggregate
    │     └── device.upload(device=k)
    └── eval

Spans are emitted at *exit*, so a JSONL trace lists children before
their parents; ``build_tree`` buffers the whole record list and links
in a second pass.  Pre-v4 traces have no span ids — ``iter_spans``
returns their stages as parentless nodes, so every consumer
(export/diff/dash) degrades gracefully on old traces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

from . import events as ev


@dataclasses.dataclass
class SpanNode:
    """One node of the reconstructed span tree."""

    name: str
    t0_s: float
    dur_s: float
    span_id: Optional[int] = None
    parent_id: Optional[int] = None
    round: Optional[int] = None
    #: "stage" for legacy-shaped stage records, "span" otherwise.
    kind: str = "span"
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    children: List["SpanNode"] = dataclasses.field(default_factory=list)
    parent: Optional["SpanNode"] = dataclasses.field(
        default=None, repr=False)

    @property
    def end_s(self) -> float:
        return self.t0_s + self.dur_s

    def self_s(self) -> float:
        """Duration not covered by child spans (the node's own time)."""
        return max(self.dur_s - sum(c.dur_s for c in self.children), 0.0)

    def path(self) -> str:
        """Root-to-node name path, e.g. ``round/power/power.ccp_iter``."""
        parts = [self.name]
        node = self.parent
        while node is not None:
            parts.append(node.name)
            node = node.parent
        return "/".join(reversed(parts))

    def walk(self) -> Iterable["SpanNode"]:
        yield self
        for c in self.children:
            yield from c.walk()


def _records(trace: Iterable[Any]) -> List[Dict[str, Any]]:
    return [r.to_record() if hasattr(r, "to_record") else r for r in trace]


def iter_spans(trace: Iterable[Any]) -> List[SpanNode]:
    """All span-shaped records of a trace as flat (unlinked) nodes.

    Accepts raw record dicts or live event objects.  Stage records
    without span ids (pre-v4 traces, hand-built events) become
    parentless nodes so old traces keep working.
    """
    out: List[SpanNode] = []
    for r in _records(trace):
        e = ev.parse_record(r)
        if isinstance(e, ev.SpanEvent):
            out.append(SpanNode(name=e.name, t0_s=e.t0_s, dur_s=e.dur_s,
                                span_id=e.span_id, parent_id=e.parent_id,
                                round=e.round, kind="span",
                                attrs=dict(e.attrs or {})))
        elif isinstance(e, ev.StageEvent):
            out.append(SpanNode(name=e.stage, t0_s=e.t0_s, dur_s=e.dur_s,
                                span_id=e.span_id, parent_id=e.parent_id,
                                round=e.round, kind="stage"))
    return out


def build_tree(trace: Iterable[Any],
               strict: bool = False
               ) -> Tuple[List[SpanNode], List[SpanNode]]:
    """Link a trace's spans into trees; returns ``(roots, orphans)``.

    ``roots`` are spans without a parent id (per-round ``round`` spans,
    pre-v4 stages); ``orphans`` are spans whose ``parent_id`` does not
    resolve — expected only as crash debris (a parent that never
    exited).  ``strict=True`` raises on orphans instead, which is what
    the test suite uses to assert tree validity.  Children are sorted
    by start time.
    """
    nodes = iter_spans(trace)
    by_id = {n.span_id: n for n in nodes if n.span_id is not None}
    roots: List[SpanNode] = []
    orphans: List[SpanNode] = []
    for n in nodes:
        if n.parent_id is None:
            roots.append(n)
        elif n.parent_id in by_id:
            parent = by_id[n.parent_id]
            n.parent = parent
            parent.children.append(n)
        else:
            orphans.append(n)
    if strict and orphans:
        names = sorted({o.name for o in orphans})
        raise ValueError(f"{len(orphans)} orphan span(s) with unresolved "
                         f"parent_id: {names}")
    for n in nodes:
        n.children.sort(key=lambda c: c.t0_s)
    roots.sort(key=lambda n: n.t0_s)
    return roots, orphans


def self_seconds_by_path(trace: Iterable[Any]) -> Dict[str, float]:
    """Aggregate *self* time (span duration minus child durations) by
    root-to-node name path — the attribution map ``repro.obs.diff``
    ranks: deltas land on the deepest span responsible, not on every
    enclosing parent."""
    roots, orphans = build_tree(trace)
    out: Dict[str, float] = {}
    for root in roots + orphans:
        for node in root.walk():
            out[node.path()] = out.get(node.path(), 0.0) + node.self_s()
    return out
