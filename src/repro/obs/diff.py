"""Explain why two traces differ.

    PYTHONPATH=src python -m repro.obs diff base.jsonl head.jsonl

Two runs of the same workload rarely differ uniformly — a regression
lives in *one* solver, *one* stage, *one* device.  ``diff`` therefore
attributes deltas to the deepest responsible owner rather than to
aggregates:

* **wall-clock** — per span path (``round/power/power.ccp_iter``),
  using *self* time (span duration minus child durations) so a slow
  leaf is named instead of every ancestor that contains it;
* **energy** — the eq. 16-18 per-device terms, so one hot device shows
  up by index instead of disappearing into the fleet sum;
* **solver counters** — swaps, sweeps, CCP iterations, GP steps,
  infeasible calls (deterministic per seed: growth = more work);
* **faults** — per kind (and per ``solver->target`` for fallbacks);
  a fallback that fires in one trace but not the other is *the*
  explanation and outranks timing noise in the headline.

``benchmarks/regress.py`` points at this tool when its gate trips.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

from . import events as ev
from . import spans as spans_mod
from . import summary as summary_mod


def _records(trace: Iterable[Any]) -> List[Dict[str, Any]]:
    return [r.to_record() if hasattr(r, "to_record") else r for r in trace]


def _fault_key(e: ev.FaultEvent) -> str:
    d = e.detail or {}
    if "solver" in d and "to" in d:
        return f"{e.kind}[{d['solver']}->{d['to']}]"
    if "solver" in d:
        return f"{e.kind}[{d['solver']}]"
    return e.kind


def _fault_counts(records: List[Dict[str, Any]]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for r in records:
        e = ev.parse_record(r)
        if isinstance(e, ev.FaultEvent):
            k = _fault_key(e)
            out[k] = out.get(k, 0) + 1
    return out


def _device_energy(records: List[Dict[str, Any]]
                   ) -> Dict[int, Tuple[float, float]]:
    """Per-device (E^cmp, E^com) summed over rounds."""
    out: Dict[int, Tuple[float, float]] = {}
    for r in records:
        e = ev.parse_record(r)
        if isinstance(e, ev.DeviceEvent):
            for k, (cmp_j, com_j) in enumerate(zip(e.energy_cmp_j,
                                                   e.energy_com_j)):
                a, b = out.get(k, (0.0, 0.0))
                out[k] = (a + cmp_j, b + com_j)
    return out


@dataclasses.dataclass
class TraceDiff:
    """Structured base-vs-head comparison; ``render()`` prints it."""

    base_rounds: int
    head_rounds: int
    base_wall_s: float
    head_wall_s: float
    #: (path, base_s, head_s) sorted by |delta| descending.
    wall_by_path: List[Tuple[str, float, float]]
    #: (device, base_J, head_J) total energy, by |delta| descending.
    energy_by_device: List[Tuple[int, float, float]]
    #: (solver.counter, base, head) numeric counters that changed.
    counters: List[Tuple[str, float, float]]
    #: (fault key, base count, head count) where counts differ.
    faults: List[Tuple[str, int, int]]

    def headline(self) -> str:
        """The single most significant difference.  Structural changes
        (fault/fallback counts) outrank wall-clock, which is noisy."""
        if self.faults:
            key, b, h = self.faults[0]
            return (f"fault activity changed: {key} {b} -> {h} "
                    f"({h - b:+d})")
        if self.wall_by_path:
            path, b, h = self.wall_by_path[0]
            return f"largest wall-clock delta: {path} ({h - b:+.4f}s)"
        if self.counters:
            name, b, h = self.counters[0]
            return f"largest counter delta: {name} {b:g} -> {h:g}"
        return "traces are equivalent under every diff dimension"

    def render(self, top: int = 8) -> str:
        lines = []
        dw = self.head_wall_s - self.base_wall_s
        pct = (f" ({dw / self.base_wall_s:+.1%})"
               if self.base_wall_s > 0 else "")
        lines.append(f"rounds: {self.base_rounds} -> {self.head_rounds}; "
                     f"round wall-clock: {self.base_wall_s:.4f}s -> "
                     f"{self.head_wall_s:.4f}s ({dw:+.4f}s{pct})")
        if self.faults:
            lines.append("fault/fallback deltas:")
            for key, b, h in self.faults[:top]:
                lines.append(f"  {h - b:+4d}  {key}  ({b} -> {h})")
        if self.wall_by_path:
            lines.append("wall-clock contributors (self time by span "
                         "path, largest first):")
            for path, b, h in self.wall_by_path[:top]:
                lines.append(f"  {h - b:+.4f}s  {path}  "
                             f"({b:.4f}s -> {h:.4f}s)")
        if self.counters:
            lines.append("solver counter deltas:")
            for name, b, h in self.counters[:top]:
                lines.append(f"  {h - b:+g}  {name}  ({b:g} -> {h:g})")
        if self.energy_by_device:
            lines.append("energy contributors (per device, E^cmp+E^com):")
            for k, b, h in self.energy_by_device[:top]:
                lines.append(f"  {h - b:+.3e}J  device {k}  "
                             f"({b:.3e}J -> {h:.3e}J)")
        lines.append(f"headline: {self.headline()}")
        return "\n".join(lines)


def diff_traces(base: Iterable[Any], head: Iterable[Any],
                min_wall_delta_s: float = 1e-4) -> TraceDiff:
    """Compare two traces (raw records or live events)."""
    base_r, head_r = _records(base), _records(head)
    sb = summary_mod.summarize(base_r)
    sh = summary_mod.summarize(head_r)

    # wall-clock per deepest responsible span path
    wb = spans_mod.self_seconds_by_path(base_r)
    wh = spans_mod.self_seconds_by_path(head_r)
    wall = [(p, wb.get(p, 0.0), wh.get(p, 0.0))
            for p in sorted(set(wb) | set(wh))]
    wall = [(p, b, h) for p, b, h in wall
            if abs(h - b) >= min_wall_delta_s]
    wall.sort(key=lambda t: -abs(t[2] - t[1]))

    # per-device energy totals
    eb, eh = _device_energy(base_r), _device_energy(head_r)
    energy = []
    for k in sorted(set(eb) | set(eh)):
        b = sum(eb.get(k, (0.0, 0.0)))
        h = sum(eh.get(k, (0.0, 0.0)))
        if b != h:
            energy.append((k, b, h))
    energy.sort(key=lambda t: -abs(t[2] - t[1]))

    # solver counters (numeric only; strings like method= are skipped)
    counters = []
    for solver in sorted(set(sb.solvers) | set(sh.solvers)):
        cb = sb.solvers.get(solver, {})
        ch = sh.solvers.get(solver, {})
        for key in sorted(set(cb) | set(ch)):
            b, h = cb.get(key, 0), ch.get(key, 0)
            if not (isinstance(b, (int, float))
                    and isinstance(h, (int, float))):
                continue
            if float(b) != float(h):
                counters.append((f"{solver}.{key}", float(b), float(h)))
    counters.sort(key=lambda t: -abs(t[2] - t[1]))

    # faults by key
    fb, fh = _fault_counts(base_r), _fault_counts(head_r)
    faults = [(k, fb.get(k, 0), fh.get(k, 0))
              for k in sorted(set(fb) | set(fh))
              if fb.get(k, 0) != fh.get(k, 0)]
    faults.sort(key=lambda t: -abs(t[2] - t[1]))

    return TraceDiff(base_rounds=sb.n_rounds, head_rounds=sh.n_rounds,
                     base_wall_s=sb.total_wall_s,
                     head_wall_s=sh.total_wall_s,
                     wall_by_path=wall, energy_by_device=energy,
                     counters=counters, faults=faults)


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs diff",
        description="attribute wall-clock/energy/counter deltas between "
                    "two JSONL traces to the deepest responsible spans")
    ap.add_argument("base", help="baseline JSONL trace")
    ap.add_argument("head", help="candidate JSONL trace")
    ap.add_argument("--top", type=int, default=8,
                    help="rows per section (default 8)")
    args = ap.parse_args(argv)
    d = diff_traces(summary_mod.load_trace(args.base),
                    summary_mod.load_trace(args.head))
    print(f"trace diff: {args.base} -> {args.head}")
    print(d.render(top=args.top))


if __name__ == "__main__":
    main()
