"""Trace-tooling CLI.

    python -m repro.obs summary trace.jsonl      # CSV stage summary
    python -m repro.obs export  trace.jsonl      # Chrome/Perfetto JSON
    python -m repro.obs diff    base.jsonl head.jsonl
    python -m repro.obs dash    trace.jsonl -o report.html
    python -m repro.obs metrics trace.jsonl      # Prometheus text

``python -m repro.obs trace.jsonl`` (no subcommand) keeps the historic
behavior and prints the summary.
"""
import sys

_COMMANDS = ("summary", "export", "diff", "dash", "metrics")


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    cmd = argv[0] if argv and argv[0] in _COMMANDS else None
    if cmd is None:
        if argv and argv[0] in ("-h", "--help"):
            print(__doc__.strip())
            return
        # historic form: first arg is a trace file -> summary
        cmd, args = "summary", argv
    else:
        args = argv[1:]
    if cmd == "summary":
        from .summary import main as run
    elif cmd == "export":
        from .export import main as run
    elif cmd == "diff":
        from .diff import main as run
    elif cmd == "dash":
        from .dash import main as run
    else:
        from .metrics import main as run
    run(args)


if __name__ == "__main__":
    main()
