"""``python -m repro.obs trace.jsonl`` — print a trace's CSV summary."""
from .summary import main

main()
