"""Real MNIST/Fashion-MNIST loader (IDX format) with synthetic fallback.

The container is offline; if the standard IDX files exist under
``root`` (train-images-idx3-ubyte[.gz] etc.) they are parsed directly
(no torchvision/tf dependency), otherwise the synthetic generator with
identical shapes/statistics is returned so every experiment still runs.
"""
from __future__ import annotations

import gzip
import os
import struct
from typing import Optional, Tuple

import numpy as np

from .synthetic import SyntheticImages

_FILES = {
    "train_images": "train-images-idx3-ubyte",
    "train_labels": "train-labels-idx1-ubyte",
    "test_images": "t10k-images-idx3-ubyte",
    "test_labels": "t10k-labels-idx1-ubyte",
}


def _open(path: str):
    if os.path.exists(path + ".gz"):
        return gzip.open(path + ".gz", "rb")
    return open(path, "rb")


def _read_idx(path: str) -> np.ndarray:
    with _open(path) as f:
        magic = struct.unpack(">I", f.read(4))[0]
        ndim = magic & 0xFF
        dims = struct.unpack(f">{ndim}I", f.read(4 * ndim))
        data = np.frombuffer(f.read(), dtype=np.uint8)
    return data.reshape(dims)


def available(root: str) -> bool:
    return all(os.path.exists(os.path.join(root, f))
               or os.path.exists(os.path.join(root, f + ".gz"))
               for f in _FILES.values())


def load_mnist(root: str = "data/mnist",
               fallback_n: Tuple[int, int] = (60000, 10000),
               fallback_side: int = 28,
               seed: int = 0) -> Tuple[SyntheticImages, SyntheticImages]:
    """Returns (train, test) as SyntheticImages containers.

    Uses the real IDX files when present; otherwise the synthetic
    class-conditional generator (documented fallback, DESIGN.md §7).
    """
    if available(root):
        tr_x = _read_idx(os.path.join(root,
                                      _FILES["train_images"])).astype(
            np.float32) / 255.0
        tr_y = _read_idx(os.path.join(root,
                                      _FILES["train_labels"])).astype(
            np.int32)
        te_x = _read_idx(os.path.join(root,
                                      _FILES["test_images"])).astype(
            np.float32) / 255.0
        te_y = _read_idx(os.path.join(root,
                                      _FILES["test_labels"])).astype(
            np.int32)
        train = SyntheticImages(images=tr_x, labels=tr_y.copy(),
                                true_labels=tr_y, num_classes=10)
        test = SyntheticImages(images=te_x, labels=te_y.copy(),
                               true_labels=te_y, num_classes=10)
        return train, test
    train = SyntheticImages.make(fallback_n[0], side=fallback_side,
                                 seed=seed)
    test = SyntheticImages.make(fallback_n[1], side=fallback_side,
                                seed=seed + 1)
    return train, test
