"""Federated (non-IID) data placement, mirroring paper §VI-A:
each device holds |D_k| samples of a single label; every round it
samples |D̂_k| of them; a proportion rho_k is mislabeled."""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .mislabel import mislabel
from .synthetic import SyntheticImages


@dataclasses.dataclass
class FederatedDataset:
    """Per-device shards + a common test set."""

    device_images: List[np.ndarray]   # K x (|D_k|, side, side)
    device_labels: List[np.ndarray]   # labels as *seen* (maybe corrupted)
    device_true: List[np.ndarray]     # ground-truth labels
    test_images: np.ndarray
    test_labels: np.ndarray
    num_classes: int

    @property
    def K(self) -> int:
        return len(self.device_images)

    def sample_subsets(self, rng: np.random.Generator,
                       d_hat: int) -> List[np.ndarray]:
        """Round-wise |D̂_k| sampling: index arrays per device."""
        return [rng.choice(len(imgs), size=min(d_hat, len(imgs)),
                           replace=False)
                for imgs in self.device_images]


def non_iid_split(data: SyntheticImages, test: SyntheticImages, K: int,
                  per_device: int, mislabel_prop: float,
                  seed: int = 0) -> FederatedDataset:
    """One label per device (paper: '1000 figures of one label')."""
    rng = np.random.default_rng(seed)
    dev_imgs, dev_labels, dev_true = [], [], []
    for k in range(K):
        label = k % data.num_classes
        pool = np.flatnonzero(data.true_labels == label)
        idx = rng.choice(pool, size=min(per_device, pool.size),
                         replace=False)
        imgs = data.images[idx]
        true = data.true_labels[idx]
        seen, _ = mislabel(true, mislabel_prop, data.num_classes,
                           seed=seed + 1000 + k)
        dev_imgs.append(imgs)
        dev_labels.append(seen)
        dev_true.append(true)
    return FederatedDataset(device_images=dev_imgs, device_labels=dev_labels,
                            device_true=dev_true, test_images=test.images,
                            test_labels=test.true_labels,
                            num_classes=data.num_classes)
