"""Offline synthetic datasets.

The container has no MNIST/Fashion-MNIST files, so the paper-validation
experiments run on a *class-conditional structured image generator*
with MNIST-like geometry (28x28 grayscale, 10 classes, 60k train /
10k test by default).  Each class has a fixed smooth prototype plus
per-sample jitter, so (a) a small CNN can separate the classes, and
(b) mislabeled samples produce genuinely larger gradients — the
property the paper's selection mechanism relies on.

``synthetic_lm_batch`` generates token batches for the large-model
training examples (power-law unigram distribution so losses are
non-degenerate).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _class_prototypes(num_classes: int, side: int,
                      rng: np.random.Generator) -> np.ndarray:
    """Smooth random low-frequency prototypes, one per class."""
    protos = []
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float64) / side
    for c in range(num_classes):
        img = np.zeros((side, side))
        for _ in range(4):  # few random Gabor-ish bumps per class
            cx, cy = rng.uniform(0.2, 0.8, 2)
            sx, sy = rng.uniform(0.08, 0.25, 2)
            amp = rng.uniform(0.5, 1.0) * rng.choice([-1.0, 1.0])
            img += amp * np.exp(-((xx - cx) ** 2 / (2 * sx ** 2)
                                  + (yy - cy) ** 2 / (2 * sy ** 2)))
        img = (img - img.min()) / max(img.max() - img.min(), 1e-9)
        protos.append(img)
    return np.stack(protos).astype(np.float32)


@dataclasses.dataclass
class SyntheticImages:
    """MNIST-shaped synthetic classification dataset."""

    images: np.ndarray  # (N, side, side) float32 in [0, 1]
    labels: np.ndarray  # (N,) int32 (possibly corrupted)
    true_labels: np.ndarray  # (N,) int32 ground truth
    num_classes: int

    @staticmethod
    def make(n: int, side: int = 28, num_classes: int = 10,
             noise: float = 0.25, seed: int = 0) -> "SyntheticImages":
        rng = np.random.default_rng(seed)
        # prototypes are the class definition: FIXED across splits
        # (train/test must share them), independent of ``seed``
        proto_rng = np.random.default_rng(991_000 + side)
        protos = _class_prototypes(num_classes, side, proto_rng)
        labels = rng.integers(0, num_classes, n).astype(np.int32)
        imgs = protos[labels]
        # per-sample geometric jitter: shift by up to 2px + pixel noise
        shifts = rng.integers(-2, 3, (n, 2))
        out = np.empty_like(imgs)
        for i in range(n):
            out[i] = np.roll(imgs[i], tuple(shifts[i]), axis=(0, 1))
        out += rng.normal(0, noise, out.shape).astype(np.float32)
        out = np.clip(out, 0.0, 1.0)
        return SyntheticImages(images=out, labels=labels.copy(),
                               true_labels=labels, num_classes=num_classes)

    def __len__(self) -> int:
        return self.images.shape[0]


def synthetic_lm_batch(key: Array, batch: int, seq: int,
                       vocab: int) -> dict:
    """Power-law token batch for LM training examples."""
    k1, k2 = jax.random.split(key)
    # zipf-ish: sample from a softmax over -log(rank)
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    logits = -1.1 * jnp.log(ranks)
    tokens = jax.random.categorical(k1, logits, shape=(batch, seq + 1))
    return {"tokens": tokens[:, :-1].astype(jnp.int32),
            "labels": tokens[:, 1:].astype(jnp.int32)}
