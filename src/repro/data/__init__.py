from .synthetic import SyntheticImages, synthetic_lm_batch
from .mislabel import mislabel
from .federated import FederatedDataset, non_iid_split

__all__ = ["SyntheticImages", "synthetic_lm_batch", "mislabel",
           "FederatedDataset", "non_iid_split"]
