"""Label corruption (paper §VI-A): a proportion rho_k of each device's
samples gets a *wrong* label (uniform over the other classes)."""
from __future__ import annotations

import numpy as np


def mislabel(labels: np.ndarray, proportion: float, num_classes: int,
             seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Returns (corrupted_labels, corrupted_mask)."""
    rng = np.random.default_rng(seed)
    n = labels.shape[0]
    n_bad = int(round(proportion * n))
    idx = rng.choice(n, size=n_bad, replace=False)
    corrupted = labels.copy()
    if n_bad:
        offs = rng.integers(1, num_classes, n_bad)
        corrupted[idx] = (labels[idx] + offs) % num_classes
    mask = np.zeros(n, bool)
    mask[idx] = True
    return corrupted, mask
