"""NOMA uplink channel model with SIC decoding (paper §II-C).

The server decodes, on each RB, the device with the highest channel
power gain first, treating all *weaker* co-RB devices as interference,
then subtracts and repeats.  With devices sorted ascending by gain the
interference seen by device k is I_{k,n} = sum_{t: h_t < h_k} p_t h_t + N0
(eq. (29)/(31) of the paper).

All functions operate on dense (K, N) arrays with an RB-assignment
matrix ``rho`` in {0,1}^{K x N}; they are jit-safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import SystemParams

Array = jax.Array


def interference(rho: Array, p: Array, h: Array, N0: Array) -> Array:
    """I_{k,n}: interference + noise seen by device k on RB n.

    Weaker-gain co-RB devices interfere (SIC decode order: strong first).
    Ties are broken by device index so the ordering is always strict.
    """
    K = h.shape[0]
    contrib = rho * p * h  # (K, N) received power per device/RB
    # strict ordering: (h_t, t) < (h_k, k) lexicographically
    h_t = h[:, None, :]  # (t, 1, n)
    h_k = h[None, :, :]  # (1, k, n)
    t_idx = jnp.arange(K)[:, None, None]
    k_idx = jnp.arange(K)[None, :, None]
    weaker = (h_t < h_k) | ((h_t == h_k) & (t_idx < k_idx))  # (t, k, n)
    interf = jnp.einsum("tkn,tn->kn", weaker.astype(p.dtype), contrib)
    return interf + N0


def sinr(rho: Array, p: Array, h: Array, N0: Array) -> Array:
    """Per-(device, RB) SINR under SIC."""
    return rho * p * h / interference(rho, p, h, N0)


def rate(sys: SystemParams, rho: Array, p: Array, h: Array) -> Array:
    """Achievable rate r_{k,n} [bits/s] (paper eq. below (15))."""
    return sys.B * jnp.log2(1.0 + sinr(rho, p, h, sys.N0))


def rate_per_device(sys: SystemParams, rho: Array, p: Array,
                    h: Array) -> Array:
    """sum_n r_{k,n} — each device occupies at most one RB (eq. (13))."""
    return jnp.sum(rate(sys, rho, p, h), axis=1)


def upload_feasible(sys: SystemParams, rho: Array, p: Array, h: Array,
                    alpha: Array, rtol: float = 1e-4) -> Array:
    """Constraint (16): r_k * T >= alpha_k * L, per device (boolean)."""
    lhs = rate_per_device(sys, rho, p, h) * sys.T
    rhs = alpha * sys.L
    return lhs >= rhs * (1.0 - rtol)


def assignment_valid(sys: SystemParams, rho: Array, alpha: Array) -> Array:
    """Constraints (11)-(14) as a single boolean."""
    binary = jnp.all((rho == 0) | (rho == 1))
    per_rb = jnp.all(jnp.sum(rho, axis=0) <= sys.Q)  # (12)
    per_dev = jnp.all(jnp.sum(rho, axis=1) <= 1)  # (13)
    avail = jnp.all(rho <= alpha[:, None])  # (14)
    return binary & per_rb & per_dev & avail


def rho_from_assignment(assign: Array, K: int, N: int) -> Array:
    """Dense rho from an assignment vector (K,) with values in [0,N) or -1."""
    cols = jnp.clip(assign, 0, N - 1)
    onehot = jax.nn.one_hot(cols, N, dtype=jnp.float32)
    return onehot * (assign >= 0).astype(jnp.float32)[:, None]
