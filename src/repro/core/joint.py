"""Algorithm 1: joint resource allocation + data selection, and the four
baseline schemes of paper §VI-A.

The server-side round decision is:
  1. solve Problem 3 (RB assignment + power) via Algorithm 2/3,
  2. solve Problem 4 (data selection) via Algorithms 4/5,
and ship (delta*, rho*, p*) back to the devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..obs import metrics as metrics_mod
from . import cost as cost_mod
from . import delta as delta_mod
from . import matching as matching_mod
from . import power as power_mod
from . import selection as selection_mod
from .types import RoundState, SystemParams

Array = jax.Array


@dataclasses.dataclass
class RoundDecision:
    """Server decision for one communication round."""

    rho: np.ndarray      # (K, N) RB assignment
    p: np.ndarray        # (K, N) powers
    delta: np.ndarray    # (K, J) binary data selection
    net_cost: float      # eq. (18)
    delta_obj: float     # Delta_hat(delta), eq. (26)
    objective: float     # Problem-2 objective
    feasible: bool
    swaps: int = 0
    #: available devices the matching could not give an RB (partial
    #: matching outcome, see core/matching.py) — they cannot upload.
    unmatched: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    #: solver degradations taken while producing this decision, e.g.
    #: ["matching->greedy", "ccp->closed_form"]; empty = clean solve.
    fallbacks: tuple = ()


def _finish(sys: SystemParams, rho, p, delta, state: RoundState,
            feasible: bool, swaps: int = 0, unmatched=None,
            fallbacks: tuple = (), telemetry=None) -> RoundDecision:
    tele = obs.resolve(telemetry)
    with tele.stage("objective"):
        rho_j = jnp.asarray(rho, jnp.float32)
        p_j = jnp.asarray(p, jnp.float32)
        delta_j = jnp.asarray(delta, jnp.float32)
        n_sel = jnp.sum(delta_j, axis=1)
        nc = float(cost_mod.net_cost(sys, rho_j, p_j, n_sel))
        dv = float(delta_mod.delta(sys, delta_j, state.sigma))
        obj = float(sys.lam) * dv + (1.0 - float(sys.lam)) * nc
    reg = metrics_mod.get_default()
    if reg.enabled:
        reg.counter("feel_decisions_total",
                    "round decisions evaluated (eq. 18 + eq. 26)").inc()
        reg.gauge("feel_decision_net_cost",
                  "net cost (eq. 18) of the last round decision").set(nc)
        reg.gauge("feel_decision_delta_obj",
                  "Delta_hat (eq. 26) of the last round decision").set(dv)
    if unmatched is None:
        unmatched = np.zeros(0, np.int64)
    return RoundDecision(rho=np.asarray(rho), p=np.asarray(p),
                         delta=np.asarray(delta), net_cost=nc, delta_obj=dv,
                         objective=obj, feasible=feasible, swaps=swaps,
                         unmatched=np.asarray(unmatched, np.int64),
                         fallbacks=tuple(fallbacks))


def _count_injected(kind: str) -> None:
    reg = metrics_mod.get_default()
    if reg.enabled:
        reg.counter("feel_faults_injected_total",
                    "faults injected by the FaultPlan, by kind").inc(
                        1, kind=kind)


def _count_fallback(solver: str, to: str) -> None:
    reg = metrics_mod.get_default()
    if reg.enabled:
        reg.counter("feel_fallbacks_total",
                    "solver degradations by solver and target").inc(
                        1, solver=solver, to=to)


def _greedy_fallback(sys: SystemParams, state: RoundState, tele,
                     injected: bool, reason: str):
    """Terminal link of the matching chain: greedy max-gain RB
    assignment (the baseline-3/4 construction) + exact closed-form
    powers.  Pure numpy + one closed-form solve — cannot raise."""
    h = np.asarray(state.h)
    alpha = np.asarray(state.alpha)
    with tele.span("joint.greedy_fallback", reason=reason):
        rho = _greedy_rb(sys, h, alpha, prefer_max=True)
        with tele.stage("power"):
            p, cost, ok = power_mod.allocate_power(
                sys, jnp.asarray(rho), state.h, state.alpha,
                method="closed_form", telemetry=tele)
            p = tele.block(p)
    tele.fault("fallback", injected=injected, solver="matching",
               to="greedy", reason=reason)
    _count_fallback("matching", "greedy")
    avail = np.flatnonzero(alpha > 0)
    unmatched = avail[rho[avail].sum(axis=1) <= 0]
    return rho, np.asarray(p), ok and unmatched.size == 0, unmatched


def proposed_scheme(sys: SystemParams, state: RoundState,
                    selection_method: str = "faithful",
                    power_evaluator: str = "closed_form",
                    gp_steps: int = 400,
                    gp_step0: float = 0.3,
                    matching_mode: str = "auto",
                    selection_chunk: int = 0,
                    faults=None,
                    repair_infeasible: bool = False,
                    telemetry=None) -> RoundDecision:
    """Algorithm 1 (the paper's proposed scheme).

    ``matching_mode``/``selection_chunk`` select the batched solver
    variants (core/matching.py, core/selection.py — see
    docs/solvers.md); the defaults keep small rounds on the historical
    scalar/full-matrix paths.

    ``faults``: an optional ``repro.fed.faults.RoundFaults`` whose
    ``fail_power``/``fail_matching`` flags force the corresponding
    solve to fail so the fallback chain runs (chaos testing).  The
    chain — CCP power failure -> closed-form evaluator, failed/
    infeasible matching -> greedy feasible baseline — also catches
    *natural* failures: a solver exception degrades instead of
    propagating, and every degradation is recorded as a ``fault`` trace
    event plus ``feel_fallbacks_total``.

    ``repair_infeasible``: additionally route *naturally infeasible*
    (but non-crashing) matchings through the greedy fallback when that
    repairs feasibility.  Off by default so a plain run stays
    bit-for-bit the pre-fallback behavior; ``FEELTrainer`` turns it on
    whenever its resilience layer is active.
    """
    tele = obs.resolve(telemetry)
    fallbacks = []
    evaluator = power_evaluator

    # -- forced power failure: downgrade the evaluator up front --------
    if faults is not None and faults.fail_power:
        tele.fault("solver_fail", injected=True, solver="power",
                   method=evaluator)
        _count_injected("solver_fail")
        if evaluator != "closed_form":
            tele.fault("fallback", injected=True, solver="power",
                       to="closed_form", reason="injected")
            _count_fallback("power", "closed_form")
            fallbacks.append(f"{evaluator}->closed_form")
            evaluator = "closed_form"
        # closed form is the chain's terminal link: nothing to degrade
        # to — the injected failure is recorded and the solve proceeds.

    # -- matching with the greedy terminal fallback --------------------
    match = None
    if faults is not None and faults.fail_matching:
        tele.fault("solver_fail", injected=True, solver="matching")
        _count_injected("solver_fail")
        matching_reason = "injected"
    else:
        matching_reason = None
        try:
            match = matching_mod.swap_matching(
                sys, state.h, state.alpha, evaluator=evaluator,
                mode=(matching_mode if evaluator == "closed_form"
                      else "auto"),
                telemetry=tele)
        except Exception as e:  # degrade, don't die
            matching_reason = type(e).__name__
            tele.fault("solver_fail", injected=False, solver="matching",
                       reason=matching_reason)
            if evaluator != "closed_form":
                # the CCP scorer may be the culprit: retry the matching
                # with the exact closed-form evaluator first
                tele.fault("fallback", injected=False, solver="power",
                           to="closed_form", reason=matching_reason)
                _count_fallback("power", "closed_form")
                fallbacks.append(f"{evaluator}->closed_form")
                evaluator = "closed_form"
                try:
                    match = matching_mod.swap_matching(
                        sys, state.h, state.alpha, evaluator=evaluator,
                        mode=matching_mode, telemetry=tele)
                except Exception as e2:  # pragma: no cover - double fail
                    matching_reason = type(e2).__name__

    if match is not None and match.feasible:
        rho, p = match.rho, match.p
        feasible, swaps, unmatched = True, match.swaps, match.unmatched
    elif match is not None:
        # naturally infeasible (but non-crashing) matching: with the
        # resilience layer active, try the greedy terminal fallback —
        # it often repairs feasibility (max-gain assignments need less
        # power).  Otherwise keep the infeasible decision so a plain
        # run stays bit-identical to the pre-fallback behavior.
        repaired = False
        if repair_infeasible:
            rho_g, p_g, ok_g, un_g = _greedy_fallback(
                sys, state, tele, injected=False, reason="infeasible")
            if ok_g:
                rho, p, feasible, swaps = rho_g, p_g, True, 0
                unmatched = un_g
                fallbacks.append("matching->greedy")
                repaired = True
        if not repaired:
            rho, p = match.rho, match.p
            feasible, swaps = False, match.swaps
            unmatched = match.unmatched
    else:
        rho, p, feasible, unmatched = _greedy_fallback(
            sys, state, tele,
            injected=bool(faults is not None and faults.fail_matching),
            reason=matching_reason or "unknown")
        swaps = 0
        fallbacks.append("matching->greedy")

    with tele.stage("selection"):
        delta = tele.block(selection_mod.solve_selection(
            sys, state.sigma, state.sigma_mask, method=selection_method,
            steps=gp_steps, step0=gp_step0,
            device_chunk=selection_chunk, telemetry=tele))
    return _finish(sys, rho, p, delta, state, feasible=feasible,
                   swaps=swaps, unmatched=unmatched,
                   fallbacks=tuple(fallbacks), telemetry=tele)


# --------------------------------------------------------------------------
# Baselines 1-4 (paper §VI-A).  Data: random half / all samples.
# RB: each device prefers its min- / max-gain RB (greedy, capacity Q).
# Power for all baselines comes from Algorithm 3's problem — we use the
# exact closed form (identical optimum).
# --------------------------------------------------------------------------

def _greedy_rb(sys: SystemParams, h: np.ndarray, alpha: np.ndarray,
               prefer_max: bool) -> np.ndarray:
    K, N, Q = sys.K, sys.N, sys.Q
    assign = np.full(K, -1, np.int64)
    slots = np.full(N, Q, np.int64)
    for k in np.flatnonzero(alpha > 0):
        prefs = np.argsort(-h[k] if prefer_max else h[k], kind="stable")
        for n in prefs:
            if slots[n] > 0:
                assign[k] = n
                slots[n] -= 1
                break
    rho = np.zeros((K, N), np.float32)
    m = assign >= 0
    rho[np.flatnonzero(m), assign[m]] = 1.0
    return rho


def _random_half(key: jax.Array, mask: Array) -> Array:
    """Random half of each device's samples (at least one)."""
    scores = jax.random.uniform(key, mask.shape) * mask
    n_valid = jnp.sum(mask, axis=1)
    want = jnp.maximum(jnp.floor(n_valid / 2.0), 1.0)
    ranks = jnp.argsort(jnp.argsort(-scores, axis=1), axis=1)
    return (ranks < want[:, None]).astype(jnp.float32) * mask


def baseline_scheme(sys: SystemParams, state: RoundState, index: int,
                    key: Optional[jax.Array] = None,
                    telemetry=None) -> RoundDecision:
    """Baselines 1-4: (half|all data) x (min|max gain RB)."""
    if index not in (1, 2, 3, 4):
        raise ValueError("baseline index must be 1..4")
    tele = obs.resolve(telemetry)
    half = index in (1, 2)
    prefer_max = index in (2, 4)
    with tele.stage("selection"):
        if half:
            assert key is not None, "baselines 1/2 need a PRNG key"
            delta = tele.block(_random_half(key, state.sigma_mask))
        else:
            delta = state.sigma_mask
    h = np.asarray(state.h)
    alpha = np.asarray(state.alpha)
    with tele.stage("matching"):
        rho = _greedy_rb(sys, h, alpha, prefer_max)
    with tele.stage("power"):
        p, _, ok = power_mod.allocate_power(
            sys, jnp.asarray(rho), state.h, state.alpha,
            method="closed_form", telemetry=tele)
        p = tele.block(p)
    return _finish(sys, rho, p, delta, state, feasible=ok, telemetry=tele)
