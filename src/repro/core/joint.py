"""Algorithm 1: joint resource allocation + data selection, and the four
baseline schemes of paper §VI-A.

The server-side round decision is:
  1. solve Problem 3 (RB assignment + power) via Algorithm 2/3,
  2. solve Problem 4 (data selection) via Algorithms 4/5,
and ship (delta*, rho*, p*) back to the devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..obs import metrics as metrics_mod
from . import cost as cost_mod
from . import delta as delta_mod
from . import matching as matching_mod
from . import power as power_mod
from . import selection as selection_mod
from .types import RoundState, SystemParams

Array = jax.Array


@dataclasses.dataclass
class RoundDecision:
    """Server decision for one communication round."""

    rho: np.ndarray      # (K, N) RB assignment
    p: np.ndarray        # (K, N) powers
    delta: np.ndarray    # (K, J) binary data selection
    net_cost: float      # eq. (18)
    delta_obj: float     # Delta_hat(delta), eq. (26)
    objective: float     # Problem-2 objective
    feasible: bool
    swaps: int = 0


def _finish(sys: SystemParams, rho, p, delta, state: RoundState,
            feasible: bool, swaps: int = 0,
            telemetry=None) -> RoundDecision:
    tele = obs.resolve(telemetry)
    with tele.stage("objective"):
        rho_j = jnp.asarray(rho, jnp.float32)
        p_j = jnp.asarray(p, jnp.float32)
        delta_j = jnp.asarray(delta, jnp.float32)
        n_sel = jnp.sum(delta_j, axis=1)
        nc = float(cost_mod.net_cost(sys, rho_j, p_j, n_sel))
        dv = float(delta_mod.delta(sys, delta_j, state.sigma))
        obj = float(sys.lam) * dv + (1.0 - float(sys.lam)) * nc
    reg = metrics_mod.get_default()
    if reg.enabled:
        reg.counter("feel_decisions_total",
                    "round decisions evaluated (eq. 18 + eq. 26)").inc()
        reg.gauge("feel_decision_net_cost",
                  "net cost (eq. 18) of the last round decision").set(nc)
        reg.gauge("feel_decision_delta_obj",
                  "Delta_hat (eq. 26) of the last round decision").set(dv)
    return RoundDecision(rho=np.asarray(rho), p=np.asarray(p),
                         delta=np.asarray(delta), net_cost=nc, delta_obj=dv,
                         objective=obj, feasible=feasible, swaps=swaps)


def proposed_scheme(sys: SystemParams, state: RoundState,
                    selection_method: str = "faithful",
                    power_evaluator: str = "closed_form",
                    gp_steps: int = 400,
                    gp_step0: float = 0.3,
                    telemetry=None) -> RoundDecision:
    """Algorithm 1 (the paper's proposed scheme)."""
    tele = obs.resolve(telemetry)
    match = matching_mod.swap_matching(sys, state.h, state.alpha,
                                       evaluator=power_evaluator,
                                       telemetry=tele)
    with tele.stage("selection"):
        delta = tele.block(selection_mod.solve_selection(
            sys, state.sigma, state.sigma_mask, method=selection_method,
            steps=gp_steps, step0=gp_step0, telemetry=tele))
    return _finish(sys, match.rho, match.p, delta, state,
                   feasible=match.feasible, swaps=match.swaps,
                   telemetry=tele)


# --------------------------------------------------------------------------
# Baselines 1-4 (paper §VI-A).  Data: random half / all samples.
# RB: each device prefers its min- / max-gain RB (greedy, capacity Q).
# Power for all baselines comes from Algorithm 3's problem — we use the
# exact closed form (identical optimum).
# --------------------------------------------------------------------------

def _greedy_rb(sys: SystemParams, h: np.ndarray, alpha: np.ndarray,
               prefer_max: bool) -> np.ndarray:
    K, N, Q = sys.K, sys.N, sys.Q
    assign = np.full(K, -1, np.int64)
    slots = np.full(N, Q, np.int64)
    for k in np.flatnonzero(alpha > 0):
        prefs = np.argsort(-h[k] if prefer_max else h[k], kind="stable")
        for n in prefs:
            if slots[n] > 0:
                assign[k] = n
                slots[n] -= 1
                break
    rho = np.zeros((K, N), np.float32)
    m = assign >= 0
    rho[np.flatnonzero(m), assign[m]] = 1.0
    return rho


def _random_half(key: jax.Array, mask: Array) -> Array:
    """Random half of each device's samples (at least one)."""
    scores = jax.random.uniform(key, mask.shape) * mask
    n_valid = jnp.sum(mask, axis=1)
    want = jnp.maximum(jnp.floor(n_valid / 2.0), 1.0)
    ranks = jnp.argsort(jnp.argsort(-scores, axis=1), axis=1)
    return (ranks < want[:, None]).astype(jnp.float32) * mask


def baseline_scheme(sys: SystemParams, state: RoundState, index: int,
                    key: Optional[jax.Array] = None,
                    telemetry=None) -> RoundDecision:
    """Baselines 1-4: (half|all data) x (min|max gain RB)."""
    if index not in (1, 2, 3, 4):
        raise ValueError("baseline index must be 1..4")
    tele = obs.resolve(telemetry)
    half = index in (1, 2)
    prefer_max = index in (2, 4)
    with tele.stage("selection"):
        if half:
            assert key is not None, "baselines 1/2 need a PRNG key"
            delta = tele.block(_random_half(key, state.sigma_mask))
        else:
            delta = state.sigma_mask
    h = np.asarray(state.h)
    alpha = np.asarray(state.alpha)
    with tele.stage("matching"):
        rho = _greedy_rb(sys, h, alpha, prefer_max)
    with tele.stage("power"):
        p, _, ok = power_mod.allocate_power(
            sys, jnp.asarray(rho), state.h, state.alpha,
            method="closed_form", telemetry=tele)
        p = tele.block(p)
    return _finish(sys, rho, p, delta, state, feasible=ok, telemetry=tele)
