"""The convergence-gap objective Delta (paper eqs. (22)/(26)).

Delta(M) is the only controllable term of the one-round descent bound
(Lemma 2); minimizing it speeds up convergence.  We provide:

* ``delta_raw``  — literal eq. (26) double sum (used as oracle in tests);
* ``delta``      — the algebraically simplified, per-device decoupled
  form  Delta_hat = sum_k A_k * (sum_j delta_kj sigma_kj)/(sum_j delta_kj)
  with A_k = |D̂_k|^2/eps_k + |D̂_k|(|D̂|-|D̂_k|)  (DESIGN.md §4, tested
  equal to ``delta_raw``);
* ``objective``  — the full Problem-4 objective
  lambda * Delta_hat(delta) + (1-lambda) * C_hat(delta, rho, p).

All functions accept soft (continuous) selection variables so they can
be differentiated for the gradient-projection solver (Alg. 4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import cost as cost_mod
from .types import SystemParams

Array = jax.Array
_EPSDIV = 1e-12


def selected_mean_sigma(delta: Array, sigma: Array) -> Array:
    """(sum_j delta sigma) / (sum_j delta) per device; delta (K,J)."""
    num = jnp.sum(delta * sigma, axis=1)
    den = jnp.sum(delta, axis=1)
    return num / jnp.maximum(den, _EPSDIV)


def delta(sys: SystemParams, dlt: Array, sigma: Array) -> Array:
    """Simplified Delta_hat (eq. (26)) — O(K*J)."""
    return jnp.sum(sys.a_weights() * selected_mean_sigma(dlt, sigma))


def delta_raw(sys: SystemParams, dlt: Array, sigma: Array) -> Array:
    """Literal eq. (26) double sum — O(K^2 * J); test oracle."""
    d = sys.D_hat.astype(jnp.float32)
    mean_sel = selected_mean_sigma(dlt, sigma)  # (K,)
    own = d * d / sys.eps * mean_sel
    cross_t = d * mean_sel  # |D̂_t| * S_t/m_t
    # sum_{t != k} |D̂_k| |D̂_t| S_t/m_t
    cross = d * (jnp.sum(cross_t) - cross_t)
    return jnp.sum(own + cross)


def objective(sys: SystemParams, dlt: Array, sigma: Array,
              rho: Array, p: Array) -> Array:
    """Problem 2/4 objective: lambda*Delta_hat + (1-lambda)*C_hat (eq. (27))."""
    n_sel = jnp.sum(dlt, axis=1)
    c_hat = (cost_mod.cost_upload(sys, rho, p) + cost_mod.cost_compute(sys)
             - jnp.sum(sys.q * n_sel))
    return sys.lam * delta(sys, dlt, sigma) + (1.0 - sys.lam) * c_hat


def selection_only_objective(sys: SystemParams, dlt: Array,
                             sigma: Array) -> Array:
    """The delta-dependent part of the Problem-4 objective.

    lambda*Delta_hat(delta) - (1-lambda)*sum_k q_k sum_j delta_kj.
    (C^com and C^cmp are constants w.r.t. delta.)
    """
    n_sel = jnp.sum(dlt, axis=1)
    return (sys.lam * delta(sys, dlt, sigma)
            - (1.0 - sys.lam) * jnp.sum(sys.q * n_sel))
