"""Data selection (paper §V, Problem 4, Algorithms 4-5) + exact oracle.

Faithful pipeline
-----------------
1. *Continuous relaxation* (Alg. 4): gradient projection on (36) with a
   diminishing stepsize; the projection (37) onto
   {0 <= delta <= 1, sum_j delta_kj >= 1} decouples per device and is
   computed exactly (box clip, then capped-simplex projection via
   bisection when the clipped sum falls below 1).
2. *Binary recovery* (Alg. 5): the lambda-representation LP (39).
   Substituting b = delta, a = 1 - delta the LP objective becomes
       sum_kj [(1-delta†)^2 - (delta†)^2] delta_kj + const
     = sum_kj (1 - 2 delta†_kj) delta_kj + const,
   linear in delta over a box with the >=1-per-device constraint (a
   totally-unimodular system, as the paper's Lemma 4 argues), so the
   optimum is delta = 1[delta† > 1/2], repaired per device by selecting
   argmax_j delta†_kj when the threshold selects nothing.  This *is*
   the exact solution of (39) — no LP solver needed.

Exact oracle (beyond paper, DESIGN.md §4)
-----------------------------------------
The Problem-4 objective decouples per device into
    lambda * A_k * mean(sigma over selected) - (1-lambda) * q_k * m_k,
and for a fixed selection size m the optimum takes the m smallest
sigmas, so scanning prefix means of the sorted sigmas yields the global
optimum in O(J log J).  ``exact_selection`` is jit-able and is what the
large-model training path uses inside the jitted step.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .. import obs
from ..obs import metrics as metrics_mod
from . import delta as delta_mod
from .types import SystemParams

Array = jax.Array
_BIG = 1e30


# --------------------------------------------------------------------------
# Projection (37): per-device {0<=d<=1, sum d >= 1} Euclidean projection.
# --------------------------------------------------------------------------

def _project_one(z: Array, mask: Array) -> Array:
    """Project a single device's vector; masked entries pinned to 0."""
    clipped = jnp.clip(z, 0.0, 1.0) * mask
    need_simplex = jnp.sum(clipped) < 1.0

    def capped_simplex(z):
        # find tau with sum(clip(z + tau, 0, 1) * mask) == 1 by bisection
        lo = 1.0 / jnp.maximum(jnp.sum(mask), 1.0) - jnp.max(
            jnp.where(mask > 0, z, -_BIG))
        lo = jnp.minimum(lo, 0.0) - 1.0
        hi = 1.0 - jnp.min(jnp.where(mask > 0, z, _BIG))
        hi = jnp.maximum(hi, 0.0) + 1.0

        def body(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            s = jnp.sum(jnp.clip(z + mid, 0.0, 1.0) * mask)
            return jnp.where(s < 1.0, mid, lo), jnp.where(s < 1.0, hi, mid)

        lo, hi = jax.lax.fori_loop(0, 60, body, (lo, hi))
        tau = 0.5 * (lo + hi)
        return jnp.clip(z + tau, 0.0, 1.0) * mask

    return jnp.where(need_simplex, capped_simplex(z), clipped)


def project_feasible(z: Array, mask: Array) -> Array:
    """Projection (37), vmapped over devices. z, mask: (K, J)."""
    return jax.vmap(_project_one)(z, mask)


# --------------------------------------------------------------------------
# Algorithm 4: gradient projection on the continuous relaxation (36).
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("steps", "device_chunk"))
def gradient_projection(sys: SystemParams, sigma: Array, mask: Array,
                        steps: int = 400, step0: float = 0.3,
                        init: Array | None = None,
                        device_chunk: int = 0) -> Array:
    """Returns a stationary point delta† of (36) (continuous).

    step0 controls WHICH stationary point of the non-convex fractional
    objective the diminishing-step GP lands at: small step0 (~0.3)
    yields the threshold-like filter that keeps most samples and drops
    high-sigma outliers (the behaviour the paper's experiments rely
    on); large step0 (~5.0) chases the *global* optimum of Problem 4,
    which under the paper's lambda degenerates to ~1 sample/device and
    stalls training (EXPERIMENTS.md §Paper-validation).  Faithful
    either way — the paper does not specify the stepsize constant.

    ``device_chunk``: 0 (default) iterates the full (K, J) matrix in
    one fori_loop — the historical path.  A positive value runs the
    same iteration over device blocks of that size under one
    ``lax.scan`` (via ``lax.map``), bounding peak memory to
    O(device_chunk * J) at K=1000+ scale.  The objective (36) is
    separable per device (DESIGN.md §4: the A_k weights fold the only
    cross-device coupling, the |D̂| total, into per-device constants),
    so the chunked iterates equal the full-matrix ones device for
    device.
    """
    if init is None:
        init = 0.5 * mask
    if device_chunk and device_chunk < sigma.shape[0]:
        return _gp_chunked(sys, sigma, mask, steps, step0, init,
                           device_chunk)

    def f(d):
        # C^com/C^cmp are constants w.r.t. delta; argmin is unchanged.
        return delta_mod.selection_only_objective(sys, d * mask, sigma)

    grad_f = jax.grad(f)

    def body(v, d):
        step = step0 / (1.0 + v) ** 0.6  # sum a = inf, sum a^2 < inf
        g = grad_f(d)
        g = jnp.where(jnp.isfinite(g), g, 0.0)
        # per-device normalization: the Delta term scales like A_k/m_k,
        # which varies by orders of magnitude across devices; scale-free
        # steps keep every device's subproblem moving at the same rate.
        norm = jnp.max(jnp.abs(g), axis=1, keepdims=True)
        g = g / jnp.maximum(norm, 1e-12)
        return project_feasible(d - step * g, mask)

    return jax.lax.fori_loop(0, steps, body, init * mask)


def _gp_chunked(sys: SystemParams, sigma: Array, mask: Array, steps: int,
                step0: float, init: Array, chunk: int) -> Array:
    """Algorithm 4 over device blocks under one ``lax.map``.

    The per-chunk objective is the Problem-4 selection term restricted
    to the block, with the A_k weights (which carry the global |D̂|
    total) precomputed once — so the block gradients, normalization and
    projection are the same row-wise operations as the full-matrix
    path, and the iterates match it device for device.
    """
    K, J = sigma.shape
    lam = sys.lam
    A = sys.a_weights()
    pad = (-K) % chunk

    def padk(x):
        # padded devices have mask=0 rows: the projection pins them to 0
        # and their objective terms vanish, so they never affect the loop
        return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))

    n_blocks = (K + pad) // chunk

    def blocks(x):
        return padk(x).reshape((n_blocks, chunk) + x.shape[1:])

    def run_block(args):
        sig, msk, ini, A_b, q_b = args

        def f(d):
            dm = d * msk
            mean = (jnp.sum(dm * sig, axis=1)
                    / jnp.maximum(jnp.sum(dm, axis=1), delta_mod._EPSDIV))
            return (lam * jnp.sum(A_b * mean)
                    - (1.0 - lam) * jnp.sum(q_b * jnp.sum(dm, axis=1)))

        grad_f = jax.grad(f)

        def body(v, d):
            step = step0 / (1.0 + v) ** 0.6
            g = grad_f(d)
            g = jnp.where(jnp.isfinite(g), g, 0.0)
            norm = jnp.max(jnp.abs(g), axis=1, keepdims=True)
            g = g / jnp.maximum(norm, 1e-12)
            return project_feasible(d - step * g, msk)

        return jax.lax.fori_loop(0, steps, body, ini * msk)

    out = jax.lax.map(run_block, (blocks(sigma), blocks(mask),
                                  blocks(init), blocks(A), blocks(sys.q)))
    return out.reshape(n_blocks * chunk, J)[:K]


# --------------------------------------------------------------------------
# Algorithm 5: binary recovery via the lambda-representation LP (39).
# --------------------------------------------------------------------------

def binary_recovery(delta_cont: Array, mask: Array) -> Array:
    """Exact solution of LP (39): threshold at 1/2 with >=1 repair."""
    sel = (delta_cont > 0.5).astype(jnp.float32) * mask
    none = jnp.sum(sel, axis=1) < 1.0
    best = jnp.argmax(jnp.where(mask > 0, delta_cont, -_BIG), axis=1)
    repair = jax.nn.one_hot(best, delta_cont.shape[1], dtype=jnp.float32)
    return jnp.where(none[:, None], jnp.maximum(sel, repair * mask), sel)


def faithful_selection(sys: SystemParams, sigma: Array, mask: Array,
                       steps: int = 400, step0: float = 0.3,
                       device_chunk: int = 0) -> Array:
    """Algorithms 4 + 5 end to end (the paper's data-selection solver)."""
    d_cont = gradient_projection(sys, sigma, mask, steps=steps,
                                 step0=step0, device_chunk=device_chunk)
    return binary_recovery(d_cont, mask)


# --------------------------------------------------------------------------
# Exact per-device prefix-scan solver (beyond paper; also the jit-able
# selector used inside large-model train steps).
# --------------------------------------------------------------------------

@jax.jit
def exact_selection(sys: SystemParams, sigma: Array, mask: Array) -> Array:
    """Global optimum of Problem 4 in O(K J log J)."""
    A = sys.a_weights()  # (K,)
    big_sigma = jnp.where(mask > 0, sigma, _BIG)
    order = jnp.argsort(big_sigma, axis=1)
    sorted_sigma = jnp.take_along_axis(big_sigma, order, axis=1)
    m = jnp.arange(1, sigma.shape[1] + 1, dtype=jnp.float32)
    prefix_mean = jnp.cumsum(jnp.where(sorted_sigma < _BIG, sorted_sigma,
                                       0.0), axis=1) / m
    valid = m[None, :] <= jnp.sum(mask, axis=1, keepdims=True)
    obj = (sys.lam * A[:, None] * prefix_mean
           - (1.0 - sys.lam) * sys.q[:, None] * m[None, :])
    obj = jnp.where(valid, obj, _BIG)
    best_m = jnp.argmin(obj, axis=1) + 1  # (K,) optimal selection size
    ranks = jnp.argsort(order, axis=1)  # rank of each sample in sorted order
    return (ranks < best_m[:, None]).astype(jnp.float32) * mask


def solve_selection(sys: SystemParams, sigma: Array, mask: Array,
                    method: str = "faithful", steps: int = 400,
                    step0: float = 0.3, device_chunk: int = 0,
                    telemetry=None) -> Array:
    tele = obs.resolve(telemetry)
    reg = metrics_mod.get_default()
    if method == "faithful":
        # the two Alg. 4/5 phases as child spans of the selection stage;
        # same computation as faithful_selection (block is a no-op sync)
        with tele.span("selection.gp", steps=steps):
            d_cont = tele.block(gradient_projection(
                sys, sigma, mask, steps=steps, step0=step0,
                device_chunk=device_chunk))
        with tele.span("selection.recover"):
            out = tele.block(binary_recovery(d_cont, mask))
        gp_steps = steps
    elif method == "exact":
        with tele.span("selection.exact"):
            out = tele.block(exact_selection(sys, sigma, mask))
        gp_steps = 0
    else:
        raise ValueError(f"unknown selection method: {method}")
    if tele.enabled or reg.enabled:
        # one host sync, shared by the trace event and the metrics
        n_selected = int(jnp.sum(out))
        if tele.enabled:
            tele.solver("selection", method=method, gp_steps=gp_steps,
                        n_selected=n_selected)
        if reg.enabled:
            reg.counter("feel_selection_calls_total",
                        "data-selection solves by method").inc(
                            1, method=method)
            reg.counter("feel_selection_gp_steps_total",
                        "gradient-projection (Alg. 4) steps").inc(gp_steps)
            reg.counter("feel_selection_selected_total",
                        "samples selected across rounds").inc(n_selected)
    return out
