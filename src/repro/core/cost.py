"""Energy / reward / net-cost model (paper eqs. (7)-(18))."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import SystemParams

Array = jax.Array


def compute_time(sys: SystemParams) -> Array:
    """tau_k = F_k |D̂_k| / f_k  (eq. (8))."""
    return sys.F * sys.D_hat / sys.f


def energy_compute(sys: SystemParams) -> Array:
    """E^cmp_k = kappa F_k |D̂_k| f_k^2  (eq. (9))."""
    return sys.kappa * sys.F * sys.D_hat * sys.f ** 2


def cost_compute(sys: SystemParams) -> Array:
    """C^cmp = sum_k c_k E^cmp_k  (eq. (10)). Constant w.r.t. all decisions."""
    return jnp.sum(sys.c * energy_compute(sys))


def energy_upload(sys: SystemParams, rho: Array, p: Array) -> Array:
    """E^com_k = sum_n rho_{k,n} p_{k,n} T  (below eq. (16))."""
    return jnp.sum(rho * p, axis=1) * sys.T


def cost_upload(sys: SystemParams, rho: Array, p: Array) -> Array:
    """C^com = sum_k c_k E^com_k  (eq. (17))."""
    return jnp.sum(sys.c * energy_upload(sys, rho, p))


def reward(sys: SystemParams, n_selected: Array) -> Array:
    """R(M) = sum_k q_k |M_k|  (eq. (7)); n_selected is (K,)."""
    return jnp.sum(sys.q * n_selected)


def net_cost(sys: SystemParams, rho: Array, p: Array,
             n_selected: Array) -> Array:
    """C = C^com + C^cmp - R  (eq. (18))."""
    return (cost_upload(sys, rho, p) + cost_compute(sys)
            - reward(sys, n_selected))


def resource_cost(sys: SystemParams, rho: Array, p: Array) -> Array:
    """Objective of Problem 3: C^com + C^cmp (reward is delta-only)."""
    return cost_upload(sys, rho, p) + cost_compute(sys)
