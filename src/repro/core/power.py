"""Power allocation for a fixed RB assignment (paper §IV-B, Alg. 3).

Two solvers are provided:

1. ``ccp_power`` — the paper-faithful convex-concave procedure:
   the DC program (33) is solved by iterating the convexified
   subproblem (34).  The paper solves (34) with CVX; offline and
   TPU-native, we solve it with a log-barrier interior-point method
   written in JAX (objective is linear, the linearized rate constraint
   is concave, the box constraint is handled by a sigmoid
   reparametrization).

2. ``closed_form_power`` — beyond-paper exact solution (DESIGN.md §4):
   constraint (13) makes the program separable per RB, and under SIC
   ordering the minimum-cost point has every rate constraint tight:

       p_(r) = gamma * N0 * (1 + gamma)^r / h_(r),   r = #weaker co-RB
       gamma = 2^(L / (B*T)) - 1.

   Proof sketch: raising any power only raises the interference (hence
   the required power) of every stronger co-RB device, and all unit
   costs c_k are positive, so all-tight is optimal.  Used as the CCP
   correctness oracle and as the fast mode inside the swap matching.

Both return power matrices p with p[k, n] > 0 only where rho[k, n] = 1.
Devices with alpha_k = 0 have no rate constraint and get p = 0.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..obs import metrics as metrics_mod
from .types import SystemParams

Array = jax.Array


def snr_target(sys: SystemParams) -> Array:
    """gamma = 2^(L/(B*T)) - 1: per-device SINR needed to push L bits."""
    return 2.0 ** (sys.L / (sys.B * sys.T)) - 1.0


def _weaker(h: Array, active: Array) -> Array:
    """(t, k, n) boolean: active device t is strictly weaker than k on n."""
    K = h.shape[0]
    h_t, h_k = h[:, None, :], h[None, :, :]
    t_i = jnp.arange(K)[:, None, None]
    k_i = jnp.arange(K)[None, :, None]
    rel = (h_t < h_k) | ((h_t == h_k) & (t_i < k_i))
    return rel & (active[:, None, :] > 0)


def closed_form_power(sys: SystemParams, rho: Array, h: Array,
                      alpha: Array) -> Tuple[Array, Array]:
    """Exact minimum-cost powers; returns (p, feasible_per_device)."""
    gamma = snr_target(sys)
    active = rho * alpha[:, None]  # only available devices transmit
    rank = jnp.einsum("tkn,tn->kn", _weaker(h, active).astype(h.dtype),
                      active)
    p = active * gamma * sys.N0 * (1.0 + gamma) ** rank / jnp.maximum(h, 1e-30)
    feas = jnp.sum(p, axis=1) <= sys.p_max * (1.0 + 1e-6)
    # an available device with no RB can never satisfy (16)
    matched = jnp.sum(active, axis=1) > 0
    feas = feas & (matched | (alpha == 0))
    return p, feas


# --------------------------------------------------------------------------
# Paper-faithful Algorithm 3 (CCP) with a JAX log-barrier inner solver.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CCPResult:
    p: Array              # (K, N) final powers
    trajectory: np.ndarray  # objective value per CCP iteration (Fig. 3)
    feasible: bool
    iterations: int


def _upload_cost(sys: SystemParams, p: Array, rho: Array) -> Array:
    return jnp.sum(sys.c[:, None] * rho * p) * sys.T


def _interf_assigned(p: Array, h: Array, weaker: Array, N0: Array) -> Array:
    """I_k on each device's RB(s): (K, N)."""
    return jnp.einsum("tkn,tn->kn", weaker.astype(p.dtype), p * h) + N0


def _g_constraints(sys: SystemParams, p: Array, p_v: Array, rho: Array,
                   h: Array, alpha: Array, weaker: Array) -> Array:
    """Linearized rate constraints g_k(p; p_v) >= 0 (eq. (34)), in nats."""
    need = alpha * sys.L * jnp.log(2.0) / (sys.B * sys.T)  # (K,)
    I_v = _interf_assigned(p_v, h, weaker, sys.N0)  # at linearization point
    sig = rho * p * h
    lhs_log = jnp.log(sig + _interf_assigned(p, h, weaker, sys.N0))
    lin = (jnp.log(I_v)
           + jnp.einsum("tkn,tn->kn", weaker.astype(p.dtype),
                        (p - p_v) * h) / I_v)
    per_rb = (lhs_log - lin) * rho  # only the assigned RB counts
    return jnp.sum(per_rb, axis=1) - need


#: traced-at-trace-time compile counters for the padded barrier
#: objective, keyed on (bucket, K, N).  ``_phi_padded`` bumps its key
#: every time JAX *traces* it (i.e. on compilation, not on execution),
#: so tests can assert that a second CCP solve with a different
#: sparsity pattern but the same bucket does not recompile
#: (tests/test_power_retrace.py).
_INNER_TRACE_COUNTS: dict = {}


def inner_trace_counts() -> dict:
    """Snapshot of ``_phi_padded`` compile counts by (bucket, K, N)."""
    return dict(_INNER_TRACE_COUNTS)


def _bucket_size(m: int) -> int:
    """Pad the active-variable count to the next power of two >= 4.

    The padded shapes are what the jitted barrier functions key their
    compilation cache on, so every sparsity pattern whose active count
    lands in the same bucket reuses one compiled Newton step.
    """
    b = 4
    while b < m:
        b *= 2
    return b


def _phi_padded(pvec, t, vmask, ki, ni, pmax_vec, sys, p_v, rho, h,
                alpha, weaker, mask_k):
    """Barrier objective over a padded active set.

    ``pvec``/``vmask``/``ki``/``ni``/``pmax_vec`` have static bucket
    length; pad slots carry vmask=0, scatter to (0, 0) with zero
    contribution (``.add`` of ``pvec*vmask``), and are excluded from
    every barrier sum via the double-``where`` pattern so their
    gradients are exactly zero.
    """
    key = (pvec.shape[0],) + tuple(p_v.shape)
    _INNER_TRACE_COUNTS[key] = _INNER_TRACE_COUNTS.get(key, 0) + 1
    p = jnp.zeros(p_v.shape, p_v.dtype).at[ki, ni].add(pvec * vmask)
    g = _g_constraints(sys, p, p_v, rho, h, alpha, weaker)
    g_act = jnp.where(mask_k > 0, g, 1.0)
    pv_safe = jnp.where(vmask > 0, pvec, 0.5)
    pm_safe = jnp.where(vmask > 0, pmax_vec, 1.0)
    barrier = (-jnp.sum(jnp.where(mask_k > 0, jnp.log(g_act), 0.0))
               - jnp.sum(jnp.where(vmask > 0, jnp.log(pv_safe), 0.0))
               - jnp.sum(jnp.where(vmask > 0,
                                   jnp.log(pm_safe - pv_safe), 0.0)))
    return t * _upload_cost(sys, p, rho) + barrier


def _feasible_padded(pvec, vmask, ki, ni, pmax_vec, sys, p_v, rho, h,
                     alpha, weaker, mask_k):
    """Strict interior-point feasibility of a padded candidate."""
    p = jnp.zeros(p_v.shape, p_v.dtype).at[ki, ni].add(pvec * vmask)
    g = _g_constraints(sys, p, p_v, rho, h, alpha, weaker)
    ok_g = jnp.all(jnp.where(mask_k > 0, g > 0, True))
    ok_box = (jnp.all(jnp.where(vmask > 0, pvec > 0, True))
              & jnp.all(jnp.where(vmask > 0, pvec < pmax_vec, True)))
    return ok_g & ok_box


@functools.lru_cache(maxsize=None)
def _inner_fns(bucket: int):
    """Jitted (phi, grad, hessian, feasible) for one bucket size.

    The lru_cache keeps one jit wrapper per bucket so each wrapper's
    own compilation cache holds exactly one entry per (K, N) — module
    level, so repeated ``_inner_solve`` calls never rebuild (and hence
    never retrace) the closures the old implementation created per
    call.  ``bucket`` only keys the cache; the padded shapes passed in
    carry the actual size.
    """
    del bucket
    return (jax.jit(_phi_padded), jax.jit(jax.grad(_phi_padded)),
            jax.jit(jax.hessian(_phi_padded)), jax.jit(_feasible_padded))


def _inner_solve(sys: SystemParams, p_v: Array, rho: Array, h: Array,
                 alpha: Array, weaker: Array, mask_k: Array,
                 newton_iters: int = 25, pad_to: int | None = None) -> Array:
    """Solve the convex subproblem (34) with a feasible-start
    log-barrier interior-point method (damped Newton).

    The active variables are the (device, RB) pairs with rho=1 and
    alpha=1 — at most K of them (constraint (13)), so the Newton system
    is tiny and exact.  The barrier weight ramps geometrically; the
    final duality gap is ~(#constraints)/t_final, i.e. negligible
    relative to the upload cost by construction of the schedule.

    The active index set is padded to a bucketed static length
    (``_bucket_size``; override with ``pad_to`` — tests pass the exact
    count to compare against the effectively-unpadded solve) and the
    barrier objective/gradient/Hessian are module-level jits cached per
    bucket (``_inner_fns``), so a new sparsity pattern in an existing
    bucket re-solves without retracing.
    """
    import numpy as np

    ki, ni = np.nonzero(np.asarray(rho * alpha[:, None]) > 0)
    m = ki.size
    if m == 0:
        return jnp.zeros_like(p_v)
    b = _bucket_size(m) if pad_to is None else max(int(pad_to), m)
    pad = b - m
    ki_j = jnp.asarray(np.concatenate([ki, np.zeros(pad, ki.dtype)]))
    ni_j = jnp.asarray(np.concatenate([ni, np.zeros(pad, ni.dtype)]))
    vmask = jnp.asarray(np.arange(b) < m, p_v.dtype)
    pmax_vec = jnp.where(vmask > 0, sys.p_max[ki_j], 1.0)

    def to_mat(pvec):
        return jnp.zeros(p_v.shape, p_v.dtype).at[ki_j, ni_j].add(
            pvec * vmask)

    phi_jit, grad_fn, hess_fn, feas_fn = _inner_fns(b)
    rest = (vmask, ki_j, ni_j, pmax_vec, sys, p_v, rho, h, alpha,
            weaker, mask_k)

    def strictly_feasible(pvec):
        return bool(feas_fn(pvec, *rest))

    pvec = jnp.clip(p_v[ki_j, ni_j], 1e-12, pmax_vec * (1 - 1e-6))
    cost0 = max(float(_upload_cost(sys, to_mat(pvec), rho)), 1e-12)
    n_con = m * 2 + int(jnp.sum(mask_k))
    t = 10.0 / cost0
    t_final = 1e7 * n_con / cost0
    while t < t_final:
        for _ in range(newton_iters):
            g = grad_fn(pvec, t, *rest)
            H = hess_fn(pvec, t, *rest)
            H = H + jnp.eye(H.shape[0], dtype=H.dtype) * 1e-9
            try:
                step = jnp.linalg.solve(H, g)
            except np.linalg.LinAlgError:  # pragma: no cover - singular
                step = g
                _count_singular_newton()
            if not bool(jnp.all(jnp.isfinite(step))):
                # jnp.linalg.solve signals a singular system with
                # non-finite entries rather than raising; same fallback
                step = g
                _count_singular_newton()
            # backtracking line search keeping strict feasibility
            f0 = float(phi_jit(pvec, t, *rest))
            a = 1.0
            moved = False
            for _ in range(40):
                cand = pvec - a * step
                if strictly_feasible(cand):
                    f1 = float(phi_jit(cand, t, *rest))
                    if np.isfinite(f1) and f1 <= f0 - 1e-12 * abs(f0):
                        pvec = cand
                        moved = True
                        break
                a *= 0.5
            if not moved:
                break  # Newton converged (or stalled) at this t
        t *= 20.0
    return to_mat(pvec)


def ccp_power(sys: SystemParams, rho: Array, h: Array, alpha: Array,
              p0: Array | None = None, n_ccp: int = 8,
              tol: float = 1e-4, telemetry=None) -> CCPResult:
    """Algorithm 3: iterate the convexified subproblem until convergence.

    ``telemetry``: an ``obs`` sink; each outer CCP iteration is recorded
    as a ``power.ccp_iter`` span (child of the enclosing power stage),
    so a slow/extra iteration is attributable from the trace.
    """
    tele = obs.resolve(telemetry)
    rho = jnp.asarray(rho, jnp.float32)
    active = rho * alpha[:, None]
    weaker = _weaker(h, active)
    mask_k = (jnp.sum(active, axis=1) > 0).astype(jnp.float32) * alpha

    if p0 is None:
        p_cf, feas = closed_form_power(sys, rho, h, alpha)
        if not bool(jnp.all(feas)):
            return CCPResult(p=p_cf, trajectory=np.array([np.inf]),
                             feasible=False, iterations=0)
        # strictly feasible interior start (scaling up preserves (31))
        p0 = jnp.minimum(p_cf * 1.5, sys.p_max[:, None] * rho * (1 - 1e-4))

    p = p0 * rho
    traj = [float(_upload_cost(sys, p, rho))]
    for v in range(n_ccp):
        with tele.span("power.ccp_iter", iter=v):
            p_new = _inner_solve(sys, p, rho, h, alpha, weaker, mask_k)
            traj.append(float(_upload_cost(sys, p_new, rho)))
        if abs(traj[-1] - traj[-2]) <= tol * max(abs(traj[-2]), 1e-12):
            p = p_new
            break
        p = p_new
    return CCPResult(p=p, trajectory=np.asarray(traj), feasible=True,
                     iterations=len(traj) - 1)


def allocate_power(sys: SystemParams, rho: Array, h: Array, alpha: Array,
                   method: str = "closed_form", telemetry=None):
    """Unified entry point; returns (p, total upload cost, feasible).

    ``telemetry``: an ``obs`` sink for solver counters — ``None`` uses
    the process default; pass ``obs.NULL`` to suppress (the matching
    scorer does, so candidate evaluations don't flood the trace).
    """
    tele = obs.resolve(telemetry)
    if method == "closed_form":
        p, feas = closed_form_power(sys, rho, h, alpha)
        ok = bool(jnp.all(feas))
        cost = float(_upload_cost(sys, p, rho)) if ok else float("inf")
        tele.solver("power", method=method, feasible=ok)
        _count_power(method, ok, 0)
        return p, cost, ok
    if method == "ccp":
        res = ccp_power(sys, rho, h, alpha, telemetry=tele)
        cost = float(_upload_cost(sys, res.p, rho)) if res.feasible \
            else float("inf")
        tele.solver("power", method=method, iterations=res.iterations,
                    feasible=bool(res.feasible))
        _count_power(method, bool(res.feasible), res.iterations)
        return res.p, cost, res.feasible
    raise ValueError(f"unknown power method: {method}")


def allocate_power_safe(sys: SystemParams, rho: Array, h: Array,
                        alpha: Array, method: str = "closed_form",
                        telemetry=None, force_fail: bool = False):
    """``allocate_power`` with the fallback chain of docs/robustness.md.

    A failed CCP solve (exception, non-finite powers, infeasible
    outcome) — or a fault-plan ``force_fail`` — degrades to the exact
    closed-form evaluator instead of propagating; the degradation is
    recorded as a ``fault`` trace event and counted in
    ``feel_fallbacks_total``.  The closed form is the chain's terminal
    link: it cannot raise, and its infeasibility is an honest property
    of the assignment, reported via the ``feasible`` flag as before.

    Returns ``(p, cost, feasible, fallback)`` where ``fallback`` is
    None or the degradation label (e.g. ``"ccp->closed_form"``).
    """
    tele = obs.resolve(telemetry)
    fallback = None
    if method != "closed_form":
        failure = None
        if force_fail:
            failure = "injected"
        else:
            try:
                p, cost, ok = allocate_power(sys, rho, h, alpha,
                                             method=method, telemetry=tele)
                if not ok:
                    failure = "infeasible"
                elif not bool(jnp.all(jnp.isfinite(p))):
                    failure = "non_finite"
                else:
                    return p, cost, ok, None
            except Exception as e:  # solver blew up: degrade, don't die
                failure = type(e).__name__
        fallback = f"{method}->closed_form"
        tele.fault("fallback", injected=force_fail, solver="power",
                   to="closed_form", reason=failure)
        reg = metrics_mod.get_default()
        if reg.enabled:
            reg.counter("feel_fallbacks_total",
                        "solver degradations by solver and target").inc(
                            1, solver="power", to="closed_form")
    p, cost, ok = allocate_power(sys, rho, h, alpha, method="closed_form",
                                 telemetry=tele)
    return p, cost, ok, fallback


def _count_singular_newton() -> None:
    """A singular Newton system inside the CCP inner solve degraded the
    step to plain gradient descent — silent before, now visible via the
    existing infeasible-call metric."""
    reg = metrics_mod.get_default()
    if reg.enabled:
        reg.counter("feel_solver_infeasible_total",
                    "infeasible solver outcomes by solver").inc(
                        1, solver="power_newton")


def _count_power(method: str, feasible: bool, ccp_iterations: int) -> None:
    """Metrics for one ``allocate_power`` call.  Counters aggregate, so
    (unlike trace events) the matching scorer's per-candidate solves
    are counted too — that is the point of the infeasible-call metric.
    """
    reg = metrics_mod.get_default()
    if not reg.enabled:
        return
    reg.counter("feel_power_calls_total",
                "power allocations by method").inc(1, method=method)
    if ccp_iterations:
        reg.counter("feel_power_ccp_iterations_total",
                    "CCP (Alg. 3) outer iterations").inc(ccp_iterations)
    if not feasible:
        reg.counter("feel_solver_infeasible_total",
                    "infeasible solver outcomes by solver").inc(
                        1, solver="power")
