"""Power allocation for a fixed RB assignment (paper §IV-B, Alg. 3).

Two solvers are provided:

1. ``ccp_power`` — the paper-faithful convex-concave procedure:
   the DC program (33) is solved by iterating the convexified
   subproblem (34).  The paper solves (34) with CVX; offline and
   TPU-native, we solve it with a log-barrier interior-point method
   written in JAX (objective is linear, the linearized rate constraint
   is concave, the box constraint is handled by a sigmoid
   reparametrization).

2. ``closed_form_power`` — beyond-paper exact solution (DESIGN.md §4):
   constraint (13) makes the program separable per RB, and under SIC
   ordering the minimum-cost point has every rate constraint tight:

       p_(r) = gamma * N0 * (1 + gamma)^r / h_(r),   r = #weaker co-RB
       gamma = 2^(L / (B*T)) - 1.

   Proof sketch: raising any power only raises the interference (hence
   the required power) of every stronger co-RB device, and all unit
   costs c_k are positive, so all-tight is optimal.  Used as the CCP
   correctness oracle and as the fast mode inside the swap matching.

Both return power matrices p with p[k, n] > 0 only where rho[k, n] = 1.
Devices with alpha_k = 0 have no rate constraint and get p = 0.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..obs import metrics as metrics_mod
from .types import SystemParams

Array = jax.Array


def snr_target(sys: SystemParams) -> Array:
    """gamma = 2^(L/(B*T)) - 1: per-device SINR needed to push L bits."""
    return 2.0 ** (sys.L / (sys.B * sys.T)) - 1.0


def _weaker(h: Array, active: Array) -> Array:
    """(t, k, n) boolean: active device t is strictly weaker than k on n."""
    K = h.shape[0]
    h_t, h_k = h[:, None, :], h[None, :, :]
    t_i = jnp.arange(K)[:, None, None]
    k_i = jnp.arange(K)[None, :, None]
    rel = (h_t < h_k) | ((h_t == h_k) & (t_i < k_i))
    return rel & (active[:, None, :] > 0)


def closed_form_power(sys: SystemParams, rho: Array, h: Array,
                      alpha: Array) -> Tuple[Array, Array]:
    """Exact minimum-cost powers; returns (p, feasible_per_device)."""
    gamma = snr_target(sys)
    active = rho * alpha[:, None]  # only available devices transmit
    rank = jnp.einsum("tkn,tn->kn", _weaker(h, active).astype(h.dtype),
                      active)
    p = active * gamma * sys.N0 * (1.0 + gamma) ** rank / jnp.maximum(h, 1e-30)
    feas = jnp.sum(p, axis=1) <= sys.p_max * (1.0 + 1e-6)
    # an available device with no RB can never satisfy (16)
    matched = jnp.sum(active, axis=1) > 0
    feas = feas & (matched | (alpha == 0))
    return p, feas


# --------------------------------------------------------------------------
# Paper-faithful Algorithm 3 (CCP) with a JAX log-barrier inner solver.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CCPResult:
    p: Array              # (K, N) final powers
    trajectory: np.ndarray  # objective value per CCP iteration (Fig. 3)
    feasible: bool
    iterations: int


def _upload_cost(sys: SystemParams, p: Array, rho: Array) -> Array:
    return jnp.sum(sys.c[:, None] * rho * p) * sys.T


def _interf_assigned(p: Array, h: Array, weaker: Array, N0: Array) -> Array:
    """I_k on each device's RB(s): (K, N)."""
    return jnp.einsum("tkn,tn->kn", weaker.astype(p.dtype), p * h) + N0


def _g_constraints(sys: SystemParams, p: Array, p_v: Array, rho: Array,
                   h: Array, alpha: Array, weaker: Array) -> Array:
    """Linearized rate constraints g_k(p; p_v) >= 0 (eq. (34)), in nats."""
    need = alpha * sys.L * jnp.log(2.0) / (sys.B * sys.T)  # (K,)
    I_v = _interf_assigned(p_v, h, weaker, sys.N0)  # at linearization point
    sig = rho * p * h
    lhs_log = jnp.log(sig + _interf_assigned(p, h, weaker, sys.N0))
    lin = (jnp.log(I_v)
           + jnp.einsum("tkn,tn->kn", weaker.astype(p.dtype),
                        (p - p_v) * h) / I_v)
    per_rb = (lhs_log - lin) * rho  # only the assigned RB counts
    return jnp.sum(per_rb, axis=1) - need


def _inner_solve(sys: SystemParams, p_v: Array, rho: Array, h: Array,
                 alpha: Array, weaker: Array, mask_k: Array,
                 newton_iters: int = 25) -> Array:
    """Solve the convex subproblem (34) with a feasible-start
    log-barrier interior-point method (damped Newton).

    The active variables are the (device, RB) pairs with rho=1 and
    alpha=1 — at most K of them (constraint (13)), so the Newton system
    is tiny and exact.  The barrier weight ramps geometrically; the
    final duality gap is ~(#constraints)/t_final, i.e. negligible
    relative to the upload cost by construction of the schedule.
    """
    import numpy as np

    ki, ni = np.nonzero(np.asarray(rho * alpha[:, None]) > 0)
    if ki.size == 0:
        return jnp.zeros_like(p_v)
    ki_j, ni_j = jnp.asarray(ki), jnp.asarray(ni)
    pmax_vec = sys.p_max[ki_j]
    K, N = p_v.shape

    def to_mat(pvec):
        return jnp.zeros((K, N), p_v.dtype).at[ki_j, ni_j].set(pvec)

    def phi(pvec, t):
        p = to_mat(pvec)
        g = _g_constraints(sys, p, p_v, rho, h, alpha, weaker)
        g_act = jnp.where(mask_k > 0, g, 1.0)
        barrier = (-jnp.sum(jnp.where(mask_k > 0, jnp.log(g_act), 0.0))
                   - jnp.sum(jnp.log(pvec))
                   - jnp.sum(jnp.log(pmax_vec - pvec)))
        return t * _upload_cost(sys, p, rho) + barrier

    def strictly_feasible(pvec):
        p = to_mat(pvec)
        g = _g_constraints(sys, p, p_v, rho, h, alpha, weaker)
        ok_g = jnp.all(jnp.where(mask_k > 0, g > 0, True))
        return bool(ok_g & jnp.all(pvec > 0) & jnp.all(pvec < pmax_vec))

    grad_fn = jax.jit(jax.grad(phi))
    hess_fn = jax.jit(jax.hessian(phi))
    phi_jit = jax.jit(phi)

    pvec = jnp.clip(p_v[ki_j, ni_j], 1e-12, pmax_vec * (1 - 1e-6))
    cost0 = max(float(_upload_cost(sys, to_mat(pvec), rho)), 1e-12)
    n_con = ki.size * 2 + int(jnp.sum(mask_k))
    t = 10.0 / cost0
    t_final = 1e7 * n_con / cost0
    while t < t_final:
        for _ in range(newton_iters):
            g = grad_fn(pvec, t)
            H = hess_fn(pvec, t)
            H = H + jnp.eye(H.shape[0], dtype=H.dtype) * 1e-9
            try:
                step = jnp.linalg.solve(H, g)
            except np.linalg.LinAlgError:  # pragma: no cover - singular
                step = g
                _count_singular_newton()
            if not bool(jnp.all(jnp.isfinite(step))):
                # jnp.linalg.solve signals a singular system with
                # non-finite entries rather than raising; same fallback
                step = g
                _count_singular_newton()
            # backtracking line search keeping strict feasibility
            f0 = float(phi_jit(pvec, t))
            a = 1.0
            moved = False
            for _ in range(40):
                cand = pvec - a * step
                if strictly_feasible(cand):
                    f1 = float(phi_jit(cand, t))
                    if np.isfinite(f1) and f1 <= f0 - 1e-12 * abs(f0):
                        pvec = cand
                        moved = True
                        break
                a *= 0.5
            if not moved:
                break  # Newton converged (or stalled) at this t
        t *= 20.0
    return to_mat(pvec)


def ccp_power(sys: SystemParams, rho: Array, h: Array, alpha: Array,
              p0: Array | None = None, n_ccp: int = 8,
              tol: float = 1e-4, telemetry=None) -> CCPResult:
    """Algorithm 3: iterate the convexified subproblem until convergence.

    ``telemetry``: an ``obs`` sink; each outer CCP iteration is recorded
    as a ``power.ccp_iter`` span (child of the enclosing power stage),
    so a slow/extra iteration is attributable from the trace.
    """
    tele = obs.resolve(telemetry)
    rho = jnp.asarray(rho, jnp.float32)
    active = rho * alpha[:, None]
    weaker = _weaker(h, active)
    mask_k = (jnp.sum(active, axis=1) > 0).astype(jnp.float32) * alpha

    if p0 is None:
        p_cf, feas = closed_form_power(sys, rho, h, alpha)
        if not bool(jnp.all(feas)):
            return CCPResult(p=p_cf, trajectory=np.array([np.inf]),
                             feasible=False, iterations=0)
        # strictly feasible interior start (scaling up preserves (31))
        p0 = jnp.minimum(p_cf * 1.5, sys.p_max[:, None] * rho * (1 - 1e-4))

    p = p0 * rho
    traj = [float(_upload_cost(sys, p, rho))]
    for v in range(n_ccp):
        with tele.span("power.ccp_iter", iter=v):
            p_new = _inner_solve(sys, p, rho, h, alpha, weaker, mask_k)
            traj.append(float(_upload_cost(sys, p_new, rho)))
        if abs(traj[-1] - traj[-2]) <= tol * max(abs(traj[-2]), 1e-12):
            p = p_new
            break
        p = p_new
    return CCPResult(p=p, trajectory=np.asarray(traj), feasible=True,
                     iterations=len(traj) - 1)


def allocate_power(sys: SystemParams, rho: Array, h: Array, alpha: Array,
                   method: str = "closed_form", telemetry=None):
    """Unified entry point; returns (p, total upload cost, feasible).

    ``telemetry``: an ``obs`` sink for solver counters — ``None`` uses
    the process default; pass ``obs.NULL`` to suppress (the matching
    scorer does, so candidate evaluations don't flood the trace).
    """
    tele = obs.resolve(telemetry)
    if method == "closed_form":
        p, feas = closed_form_power(sys, rho, h, alpha)
        ok = bool(jnp.all(feas))
        cost = float(_upload_cost(sys, p, rho)) if ok else float("inf")
        tele.solver("power", method=method, feasible=ok)
        _count_power(method, ok, 0)
        return p, cost, ok
    if method == "ccp":
        res = ccp_power(sys, rho, h, alpha, telemetry=tele)
        cost = float(_upload_cost(sys, res.p, rho)) if res.feasible \
            else float("inf")
        tele.solver("power", method=method, iterations=res.iterations,
                    feasible=bool(res.feasible))
        _count_power(method, bool(res.feasible), res.iterations)
        return res.p, cost, res.feasible
    raise ValueError(f"unknown power method: {method}")


def allocate_power_safe(sys: SystemParams, rho: Array, h: Array,
                        alpha: Array, method: str = "closed_form",
                        telemetry=None, force_fail: bool = False):
    """``allocate_power`` with the fallback chain of docs/robustness.md.

    A failed CCP solve (exception, non-finite powers, infeasible
    outcome) — or a fault-plan ``force_fail`` — degrades to the exact
    closed-form evaluator instead of propagating; the degradation is
    recorded as a ``fault`` trace event and counted in
    ``feel_fallbacks_total``.  The closed form is the chain's terminal
    link: it cannot raise, and its infeasibility is an honest property
    of the assignment, reported via the ``feasible`` flag as before.

    Returns ``(p, cost, feasible, fallback)`` where ``fallback`` is
    None or the degradation label (e.g. ``"ccp->closed_form"``).
    """
    tele = obs.resolve(telemetry)
    fallback = None
    if method != "closed_form":
        failure = None
        if force_fail:
            failure = "injected"
        else:
            try:
                p, cost, ok = allocate_power(sys, rho, h, alpha,
                                             method=method, telemetry=tele)
                if not ok:
                    failure = "infeasible"
                elif not bool(jnp.all(jnp.isfinite(p))):
                    failure = "non_finite"
                else:
                    return p, cost, ok, None
            except Exception as e:  # solver blew up: degrade, don't die
                failure = type(e).__name__
        fallback = f"{method}->closed_form"
        tele.fault("fallback", injected=force_fail, solver="power",
                   to="closed_form", reason=failure)
        reg = metrics_mod.get_default()
        if reg.enabled:
            reg.counter("feel_fallbacks_total",
                        "solver degradations by solver and target").inc(
                            1, solver="power", to="closed_form")
    p, cost, ok = allocate_power(sys, rho, h, alpha, method="closed_form",
                                 telemetry=tele)
    return p, cost, ok, fallback


def _count_singular_newton() -> None:
    """A singular Newton system inside the CCP inner solve degraded the
    step to plain gradient descent — silent before, now visible via the
    existing infeasible-call metric."""
    reg = metrics_mod.get_default()
    if reg.enabled:
        reg.counter("feel_solver_infeasible_total",
                    "infeasible solver outcomes by solver").inc(
                        1, solver="power_newton")


def _count_power(method: str, feasible: bool, ccp_iterations: int) -> None:
    """Metrics for one ``allocate_power`` call.  Counters aggregate, so
    (unlike trace events) the matching scorer's per-candidate solves
    are counted too — that is the point of the infeasible-call metric.
    """
    reg = metrics_mod.get_default()
    if not reg.enabled:
        return
    reg.counter("feel_power_calls_total",
                "power allocations by method").inc(1, method=method)
    if ccp_iterations:
        reg.counter("feel_power_ccp_iterations_total",
                    "CCP (Alg. 3) outer iterations").inc(ccp_iterations)
    if not feasible:
        reg.counter("feel_solver_infeasible_total",
                    "infeasible solver outcomes by solver").inc(
                        1, solver="power")
