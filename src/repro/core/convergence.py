"""Convergence-bound utilities (paper Lemmas 1-3).

These make the theory executable so tests/benchmarks/monitors can
check that the implementation satisfies the paper's analytical claims:

* ``aggregate`` — eq. (19), inverse-propensity-weighted aggregation;
  Lemma 1: E[g_hat] = grad L(w).
* ``one_round_bound`` — RHS of Lemma 2 for observed quantities
  (``one_round_bound_from_delta`` when the Delta term is already in
  hand, e.g. the round decision's ``delta_obj``).
* ``multi_round_bound`` — Lemma 3's product-form upper bound,
  vectorized with cumulative products; ``multi_round_bound_ref`` is
  the direct O(i^2) transcription kept as the test oracle.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from . import delta as delta_mod
from .types import SystemParams

Array = jax.Array


def aggregate(sys: SystemParams, local_grads: Array, alpha: Array) -> Array:
    """eq. (19): g_hat = (1/|D̂|) sum_k (|D̂_k|/eps_k) alpha_k g_k.

    ``local_grads``: (K, P) stacked local gradients (already averaged
    over each device's selected samples, eq. (4)).
    """
    w = (sys.D_hat / sys.eps) * alpha  # (K,)
    return jnp.einsum("k,kp->p", w, local_grads) / sys.D_hat_total


def one_round_bound_from_delta(sys: SystemParams, gap_i: Array,
                               g_norm_sq: Array, eta: Array, beta: Array,
                               d_term: Array) -> Array:
    """Lemma 2 RHS with the Delta(delta) term already evaluated
    (eq. (22)/(26) — the round decision's ``delta_obj``)."""
    return (gap_i - eta * g_norm_sq
            + beta * eta ** 2 / (2.0 * sys.D_hat_total ** 2) * d_term)


def one_round_bound(sys: SystemParams, gap_i: Array, g_norm_sq: Array,
                    eta: Array, beta: Array, dlt: Array,
                    sigma: Array) -> Array:
    """Lemma 2 RHS: E[L(w+) - L*] <= gap - eta ||g||^2 + (beta eta^2 / 2|D̂|^2) Delta."""
    d_term = delta_mod.delta(sys, dlt, sigma)
    return one_round_bound_from_delta(sys, gap_i, g_norm_sq, eta, beta,
                                      d_term)


def multi_round_bound(sys: SystemParams, gap_1: float, mu: float,
                      beta: float, etas: Sequence[float],
                      deltas: Sequence[float]) -> float:
    """Lemma 3: product contraction + weighted Delta accumulation.

    Vectorized: with f_j = 1 - 2 mu eta_j the coefficient of round t's
    Delta term is the *suffix* product a_t = prod_{j>t} f_j, computed
    for every t at once from one reversed ``jnp.cumprod``; the scalar
    transcription lives on as ``multi_round_bound_ref`` (test oracle).
    """
    if len(etas) != len(deltas):
        raise ValueError("etas and deltas must have equal length")
    if len(etas) == 0:
        return float(gap_1)
    etas_a = jnp.asarray(etas)
    deltas_a = jnp.asarray(deltas)
    f = 1.0 - 2.0 * mu * etas_a                       # (i,)
    # suffix[t] = prod_{j>t} f_j ; suffix[i-1] = 1, full product = f[0]*suffix[0]
    rev = jnp.cumprod(f[::-1])[::-1]                  # rev[t] = prod_{j>=t} f_j
    suffix = jnp.concatenate([rev[1:], jnp.ones((1,), rev.dtype)])
    acc = jnp.sum(suffix * etas_a ** 2 * deltas_a)
    prod = rev[0]
    return (float(prod) * gap_1
            + beta / (2.0 * float(sys.D_hat_total) ** 2) * float(acc))


def multi_round_bound_ref(sys: SystemParams, gap_1: float, mu: float,
                          beta: float, etas: Sequence[float],
                          deltas: Sequence[float]) -> float:
    """Direct O(i^2) transcription of Lemma 3 (oracle for the
    vectorized ``multi_round_bound``)."""
    i = len(etas)
    prod = 1.0
    for eta in etas:
        prod *= (1.0 - 2.0 * mu * eta)
    acc = 0.0
    for t in range(i):
        a_t = 1.0
        for j in range(t + 1, i):
            a_t *= (1.0 - 2.0 * mu * etas[j])
        acc += a_t * etas[t] ** 2 * deltas[t]
    total = float(jnp.asarray(prod)) * gap_1 \
        + beta / (2.0 * float(sys.D_hat_total) ** 2) * acc
    return total
