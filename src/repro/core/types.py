"""Typed containers for the FEEL system model (paper §II).

Everything is kept as plain arrays so the containers can cross the
host/jit boundary freely. ``SystemParams`` holds the static wireless /
cost / incentive constants; ``RoundState`` holds the per-round random
state (channel gains, availability draws, per-sample gradient-norm
scores sigma).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SystemParams:
    """Static FEEL system parameters (paper Table I / §VI-A defaults).

    Shapes: per-device quantities are (K,).
    """

    # -- topology -----------------------------------------------------
    K: int = dataclasses.field(metadata=dict(static=True))  # devices
    N: int = dataclasses.field(metadata=dict(static=True))  # resource blocks
    Q: int = dataclasses.field(metadata=dict(static=True))  # max devices/RB

    # -- radio --------------------------------------------------------
    B: Array  # bandwidth per RB [Hz]
    T: Array  # uplink duration [s]
    L: Array  # gradient size [bits]
    N0: Array  # noise power [W]
    p_max: Array  # (K,) max tx power [W]

    # -- compute / incentive -------------------------------------------
    q: Array  # (K,) reward per selected sample
    c: Array  # (K,) cost per Joule
    f: Array  # (K,) CPU frequency [cycles/s]
    F: Array  # (K,) CPU cycles per sample
    kappa: Array  # energy capacitance coefficient
    eps: Array  # (K,) availability probability eps_k
    D_hat: Array  # (K,) |D̂_k| sampled sub-dataset sizes

    # -- objective ------------------------------------------------------
    lam: Array  # lambda trade-off in Problem 1

    @property
    def D_hat_total(self) -> Array:
        return jnp.sum(self.D_hat)

    def a_weights(self) -> Array:
        """Per-device weights A_k of the decoupled Delta objective.

        Delta_hat(delta) = sum_k A_k * mean(sigma over selected_k) with
        A_k = |D̂_k|^2/eps_k + |D̂_k|(|D̂| - |D̂_k|)   (see DESIGN.md §4).
        """
        d = self.D_hat.astype(jnp.float32)
        total = jnp.sum(d)
        return d * d / self.eps + d * (total - d)


def default_system(K: int = 10, N: int = 5, Q: int = 2,
                   D_hat: int = 200, lam: float = 1e-3,
                   L_bits: float = 0.56e6) -> SystemParams:
    """Paper §VI-A simulation defaults.

    c_k=5, q_k=0.002 for odd k (1-indexed), c_k=10, q_k=0.005 otherwise;
    eps_k = 0.2 odd / 0.8 even; f_k = {0.1..1.0} GHz; F_k=20 cycles/sample;
    kappa=1e-28; N=5, Q=2, B=2 MHz, N0=1e-9 W, T=500 ms, lambda=1e-3.
    """
    k_idx = np.arange(1, K + 1)  # paper indexes devices from 1
    odd = (k_idx % 2) == 1
    c = np.where(odd, 5.0, 10.0)
    q = np.where(odd, 0.002, 0.005)
    eps = np.where(odd, 0.2, 0.8)
    f = (0.1 + 0.1 * ((k_idx - 1) % 10)) * 1e9
    return SystemParams(
        K=K, N=N, Q=Q,
        B=jnp.asarray(2e6), T=jnp.asarray(0.5), L=jnp.asarray(L_bits),
        N0=jnp.asarray(1e-9), p_max=jnp.full((K,), 10.0),
        q=jnp.asarray(q, jnp.float32), c=jnp.asarray(c, jnp.float32),
        f=jnp.asarray(f, jnp.float32),
        F=jnp.full((K,), 20.0), kappa=jnp.asarray(1e-28),
        eps=jnp.asarray(eps, jnp.float32),
        D_hat=jnp.full((K,), float(D_hat)),
        lam=jnp.asarray(lam),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoundState:
    """Per-round randomness: channel gains, availability, sigma scores."""

    h: Array  # (K, N) channel power gains
    alpha: Array  # (K,) availability indicators in {0, 1}
    sigma: Array  # (K, max_Dhat) per-sample ||g_{k,j}||^2 scores
    sigma_mask: Array  # (K, max_Dhat) 1 where a sample exists


def sample_round(key: jax.Array, sys: SystemParams,
                 mean_gain: float = 1e-5,
                 sigma: Optional[Array] = None) -> RoundState:
    """Draw the paper's round randomness.

    Channel gains h_{k,n} ~ Exp(mean 1e-5); alpha_k ~ Bernoulli(eps_k).
    ``sigma`` may be supplied by the training loop (real gradient norms);
    otherwise a placeholder lognormal draw is used (unit tests, benches).
    """
    kh, ka, ks = jax.random.split(key, 3)
    h = jax.random.exponential(kh, (sys.K, sys.N)) * mean_gain
    alpha = (jax.random.uniform(ka, (sys.K,)) < sys.eps).astype(jnp.float32)
    max_d = int(np.max(np.asarray(sys.D_hat)))
    if sigma is None:
        sigma = jnp.exp(jax.random.normal(ks, (sys.K, max_d)) * 0.5)
    mask = (jnp.arange(max_d)[None, :]
            < sys.D_hat.astype(jnp.int32)[:, None]).astype(jnp.float32)
    return RoundState(h=h, alpha=alpha, sigma=sigma * mask, sigma_mask=mask)
