"""Swap-matching RB assignment (paper §IV-A, Algorithm 2).

Devices and RBs form a bipartite matching (Definition 1): each
*available* device gets exactly one RB, each RB carries at most Q
devices.  Starting from an initial matching, pairs of devices exchange
RBs whenever the exchange strictly lowers the net cost (evaluated with
the power allocator of §IV-B under the candidate assignment); the loop
terminates because the cost is bounded below and strictly decreases.

Implementation notes
--------------------
* The cost of a matching is separable per RB (each device occupies one
  RB), so a swap between RBs n1, n2 only requires re-solving those two
  RBs — this is what makes the O(U^2) swap sweep cheap.
* ``evaluator="closed_form"`` (default) scores candidate assignments
  with the exact per-RB solution; ``evaluator="ccp"`` uses the
  paper-faithful Algorithm 3 (identical decisions up to solver
  tolerance — the closed form *is* the optimum of (28); verified in
  tests/test_power.py).
* In addition to pairwise swaps we allow moves into *open slots*
  (a swap with a virtual empty device), mirroring the open-house swaps
  of the housing-assignment model [37] the paper builds on.  Disable
  with ``allow_moves=False`` for the strictest reading of Alg. 2.
* Two sweep implementations share the same accept-improvement
  semantics (see docs/solvers.md): the historical ``scalar`` loop
  scores one candidate move per Python call, while ``batched`` scores
  *every* remaining candidate move of a device in one vectorized
  closed-form evaluation (``_BatchScorer``) and applies the first
  improving one in the same enumeration order — the decisions match
  the scalar path move for move, but a K=256 round runs ~K fewer
  Python-level cost evaluations per sweep.  ``mode="auto"`` (default)
  switches to the batched sweep at ``AUTO_BATCH_MIN`` available
  devices; the equivalence is enforced by
  tests/test_solver_equivalence.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .. import obs
from ..obs import metrics as metrics_mod
from . import power as power_mod
from .types import SystemParams

_INF = float("inf")

#: ``mode="auto"`` picks the batched sweep at/above this many available
#: devices; below it the scalar sweep has comparable latency and stays
#: the byte-for-byte historical path.
AUTO_BATCH_MIN = 32


@dataclasses.dataclass
class MatchingResult:
    assign: np.ndarray    # (K,) RB index per device, -1 = unmatched
    rho: np.ndarray       # (K, N) dense assignment
    p: np.ndarray         # (K, N) powers
    cost: float           # C^com (upload cost); add C^cmp for Problem-3 obj
    swaps: int
    sweeps: int
    feasible: bool
    #: available devices left without an RB (partial matching: more
    #: available devices than N*Q slots).  Empty when every available
    #: device was matched; the round can still proceed — unmatched
    #: devices simply cannot upload (their alpha-weighted IPW term is
    #: handled by the resilience layer in ``repro.fed.rounds``).
    unmatched: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    #: sweep implementation that produced this result ("scalar" or
    #: "batched"); decisions are mode-independent, the field exists so
    #: benchmarks and tests can confirm which path ran.
    mode: str = "scalar"


def _rb_cost(sys: SystemParams, members: np.ndarray, h: np.ndarray,
             c: np.ndarray, p_max: np.ndarray, gamma: float,
             N0: float, T: float) -> tuple[float, np.ndarray]:
    """Exact min upload cost of one RB given its member devices.

    ``members`` are device ids; ``h`` their gains on this RB.  Returns
    (cost, powers) with cost=inf when any power exceeds its p_max.
    """
    if members.size == 0:
        return 0.0, np.zeros((0,))
    order = np.argsort(h, kind="stable")  # ascending: weakest first
    p = np.zeros(members.size)
    cum_i = N0
    for r, idx in enumerate(order):
        p[idx] = gamma * cum_i / max(h[idx], 1e-30)
        cum_i += p[idx] * h[idx]
        if p[idx] > p_max[idx] * (1 + 1e-9):
            return _INF, p
    return float(np.sum(c * p) * T), p


class _Scorer:
    """Caches per-RB costs for the current assignment."""

    def __init__(self, sys: SystemParams, h: np.ndarray, alpha: np.ndarray,
                 evaluator: str):
        self.sys = sys
        self.h = h
        self.alpha = alpha
        self.evaluator = evaluator
        self.gamma = float(power_mod.snr_target(sys))
        self.c = np.asarray(sys.c)
        self.p_max = np.asarray(sys.p_max)
        self.N0 = float(sys.N0)
        self.T = float(sys.T)
        self.evals = 0  # candidate per-RB power solves (telemetry)

    def rb_cost(self, n: int, members: np.ndarray) -> float:
        self.evals += 1
        if self.evaluator == "closed_form":
            cost, _ = _rb_cost(self.sys, members, self.h[members, n],
                               self.c[members], self.p_max[members],
                               self.gamma, self.N0, self.T)
            return cost
        # paper-faithful: per-RB CCP (Algorithm 3) on a masked assignment
        import jax.numpy as jnp
        K, N = self.h.shape
        rho = np.zeros((K, N), np.float32)
        rho[members, n] = 1.0
        _, cost, ok = power_mod.allocate_power(
            self.sys, jnp.asarray(rho), jnp.asarray(self.h),
            jnp.asarray(self.alpha), method="ccp", telemetry=obs.NULL)
        return cost if ok else _INF


class _BatchScorer:
    """Vectorized counterpart of ``_Scorer``.

    Scores a *batch* of candidate RB member sets in one numpy
    evaluation of the exact closed-form per-RB power solution — the
    same arithmetic as ``_rb_cost`` applied row-wise in the same op
    order (so each row reproduces the scalar cost bit-for-bit for
    member counts below numpy's pairwise-sum blocking) — instead of
    one Python call per candidate.
    """

    def __init__(self, sys: SystemParams, h: np.ndarray):
        self.gamma = float(power_mod.snr_target(sys))
        self.h = h
        self.c = np.asarray(sys.c, np.float64)
        self.p_max = np.asarray(sys.p_max, np.float64)
        self.N0 = float(sys.N0)
        self.T = float(sys.T)
        self.evals = 0  # candidate per-RB power solves (telemetry)

    def rb_costs(self, ids: np.ndarray, rbs: np.ndarray) -> np.ndarray:
        """Exact min upload cost of each candidate member set.

        ``ids``: (C, Qp) member device ids with -1 padding *after* the
        real members (the scalar member-array order, so stable-sort
        tie-breaking matches ``_rb_cost``); ``rbs``: (C,) the RB each
        row is priced on.  Returns (C,) float64 costs, inf where any
        member power exceeds its p_max (same tolerance as the scalar).
        """
        C, Qp = ids.shape
        self.evals += C
        act = ids >= 0
        safe = np.where(act, ids, 0)
        h = np.where(act, self.h[safe, rbs[:, None]], _INF)
        pmax = np.where(act, self.p_max[safe], _INF)
        order = np.argsort(h, axis=1, kind="stable")  # weakest first
        h_s = np.take_along_axis(h, order, axis=1)
        act_s = np.take_along_axis(act, order, axis=1)
        pmax_s = np.take_along_axis(pmax, order, axis=1)
        p_s = np.zeros((C, Qp))
        cum = np.full(C, self.N0)
        feas = np.ones(C, bool)
        for r in range(Qp):  # SIC accumulation over <= Q rank levels
            a = act_s[:, r]
            hr = np.where(a, h_s[:, r], 0.0)  # pads carry h=inf (sort key)
            pr = np.where(a, self.gamma * cum / np.maximum(hr, 1e-30), 0.0)
            p_s[:, r] = pr
            cum = cum + np.where(a, pr * hr, 0.0)
            feas &= ~(a & (pr > pmax_s[:, r] * (1 + 1e-9)))
        p = np.zeros_like(p_s)
        np.put_along_axis(p, order, p_s, axis=1)  # back to member order
        cost = np.sum(np.where(act, self.c[safe], 0.0) * p, axis=1) * self.T
        return np.where(feas, cost, _INF)


def _batched_sweeps(sys: SystemParams, scorer: _BatchScorer,
                    avail: np.ndarray, assign: np.ndarray,
                    M: np.ndarray, counts: np.ndarray,
                    rb_costs: np.ndarray, allow_moves: bool,
                    max_sweeps: int, tele) -> tuple[int, int]:
    """The batched sweep loop; mutates ``assign``/``M``/``counts``/
    ``rb_costs`` in place and returns (swaps, sweeps).

    Replays the scalar acceptance order exactly: for each available
    device u (same order) every remaining candidate move — pairwise
    swap partners in ``avail`` order, then open-slot moves by RB index
    — is scored in ONE vectorized closed-form evaluation, and the
    first improving candidate in that enumeration order is applied;
    the remaining suffix is then re-scored under the updated
    assignment.  Decisions therefore match the scalar sweep move for
    move; only the Python-level evaluation count changes.
    """
    N, Q = sys.N, sys.Q
    Qp = M.shape[1]
    P = avail.size
    pos_sw = np.arange(P)
    pos_mv = P + np.arange(N)

    swaps = 0
    sweeps = 0
    improved = True
    while improved and sweeps < max_sweeps:
        improved = False
        sweeps += 1
        sweep_span = tele.span("matching.sweep", sweep=sweeps)
        sweep_span.__enter__()
        for u in avail:
            if assign[u] < 0:
                continue
            cursor = 0
            while True:
                n_u = assign[u]
                # -- remaining candidates, vectorized filters ----------
                swap_ok = ((avail > u) & (assign[avail] >= 0)
                           & (assign[avail] != n_u) & (pos_sw >= cursor))
                sw_pos = np.flatnonzero(swap_ok)
                partners = avail[sw_pos]
                if allow_moves:
                    mv_ok = ((np.arange(N) != n_u) & (counts < Q)
                             & (pos_mv >= cursor))
                    mv_ns = np.flatnonzero(mv_ok)
                else:
                    mv_ns = np.zeros(0, np.int64)
                C1, C2 = partners.size, mv_ns.size
                C = C1 + C2
                if C == 0:
                    break
                # -- candidate member sets (scalar member-array order) -
                base = M[n_u]
                base = base[(base != u) & (base >= 0)]  # minus the mover
                s0 = base.size
                rows_from = np.full((C, Qp), -1, np.int64)
                rows_from[:, :s0] = base
                rows_to = np.full((C, Qp), -1, np.int64)
                to_rbs = np.empty(C, np.int64)
                if C1:
                    rows_from[:C1, s0] = partners        # j joins n_u
                    n_js = assign[partners]
                    to_rbs[:C1] = n_js
                    ids0 = M[n_js]                       # (C1, Qp)
                    keep0 = (ids0 >= 0) & (ids0 != partners[:, None])
                    ordr = np.argsort(~keep0, axis=1, kind="stable")
                    comp = np.take_along_axis(
                        np.where(keep0, ids0, -1), ordr, axis=1)
                    comp[np.arange(C1), keep0.sum(axis=1)] = u  # u joins
                    rows_to[:C1] = comp
                if C2:
                    to_rbs[C1:] = mv_ns
                    rows_to[C1:] = M[mv_ns]
                    rows_to[C1 + np.arange(C2), counts[mv_ns]] = u
                # -- one vectorized closed-form evaluation -------------
                costs = scorer.rb_costs(
                    np.concatenate([rows_from, rows_to]),
                    np.concatenate([np.full(C, n_u, np.int64), to_rbs]))
                c_from, c_to = costs[:C], costs[C:]
                d = (c_from + c_to) - (rb_costs[n_u] + rb_costs[to_rbs])
                hits = np.flatnonzero(d < -1e-12)
                if hits.size == 0:
                    break
                i = int(hits[0])
                n_to = int(to_rbs[i])
                # -- apply it (the winning rows are already built) -----
                M[n_u] = rows_from[i]
                M[n_to] = rows_to[i]
                rb_costs[n_u] = c_from[i]
                rb_costs[n_to] = c_to[i]
                if i < C1:              # pairwise swap with partner j
                    j = int(partners[i])
                    assign[u], assign[j] = n_to, n_u
                    cursor = int(sw_pos[i]) + 1
                else:                   # open-slot move
                    counts[n_u] -= 1
                    counts[n_to] += 1
                    assign[u] = n_to
                    cursor = P + n_to + 1
                swaps += 1
                improved = True
        sweep_span.__exit__(None, None, None)
    return swaps, sweeps


def swap_matching(sys: SystemParams, h, alpha, evaluator: str = "closed_form",
                  allow_moves: bool = True, max_sweeps: int = 50,
                  rng: Optional[np.random.Generator] = None,
                  telemetry: Optional[obs.NullTelemetry] = None,
                  mode: str = "auto") -> MatchingResult:
    """Algorithm 2. ``h``: (K,N) gains; ``alpha``: (K,) availability.

    ``mode``: ``"scalar"`` is the historical per-candidate Python
    loop; ``"batched"`` scores all remaining candidate moves of a
    device in one vectorized closed-form evaluation (same decisions,
    see ``_batched_sweeps``); ``"auto"`` (default) picks batched for
    the closed_form evaluator with at least ``AUTO_BATCH_MIN``
    available devices, scalar otherwise.  The CCP evaluator cannot be
    vectorized per candidate and always runs scalar.
    """
    tele = obs.resolve(telemetry)
    h = np.asarray(h, np.float64)
    alpha = np.asarray(alpha, np.float64)
    K, N, Q = sys.K, sys.N, sys.Q
    avail = np.flatnonzero(alpha > 0)
    if mode not in ("auto", "scalar", "batched"):
        raise ValueError(f"unknown matching mode: {mode!r}")
    if mode == "batched" and evaluator != "closed_form":
        raise ValueError("mode='batched' requires evaluator='closed_form' "
                         "(per-candidate CCP solves cannot be vectorized); "
                         "use mode='scalar' or mode='auto'")
    use_batched = (mode == "batched"
                   or (mode == "auto" and evaluator == "closed_form"
                       and avail.size >= AUTO_BATCH_MIN))
    mode_used = "batched" if use_batched else "scalar"
    scorer = (_BatchScorer(sys, h) if use_batched
              else _Scorer(sys, h, alpha, evaluator))

    stage = tele.stage("matching")
    stage.__enter__()
    # ---- initial matching Psi_0: greedy best-gain with capacity ----
    with tele.span("matching.init"):
        assign = np.full(K, -1, np.int64)
        slots = np.full(N, Q, np.int64)
        order = avail[np.argsort(-h[avail].max(axis=1), kind="stable")]
        for k in order:
            open_rbs = np.flatnonzero(slots > 0)
            if open_rbs.size == 0:
                # More available devices than N*Q slots: Definition 1
                # cannot be satisfied, so the matching is *partial* — the
                # remaining devices stay at assign == -1 and are reported
                # in ``MatchingResult.unmatched`` (and counted in the
                # ``feel_matching_unmatched_total`` /
                # ``feel_solver_infeasible_total`` metrics below) instead
                # of being silently skipped.  The round still proceeds
                # with the devices that did get an RB.
                break
            n = open_rbs[np.argmax(h[k, open_rbs])]
            assign[k] = n
            slots[n] -= 1

        if use_batched:
            Qp = max(Q, 1)
            M = np.full((N, Qp), -1, np.int64)
            counts = np.zeros(N, np.int64)
            for n in range(N):
                ids = np.flatnonzero(assign == n)
                M[n, :ids.size] = ids
                counts[n] = ids.size
            rb_costs = scorer.rb_costs(M, np.arange(N))
        else:
            members = [np.flatnonzero(assign == n) for n in range(N)]
            rb_costs = np.array([scorer.rb_cost(n, members[n])
                                 for n in range(N)])

    if use_batched:
        swaps, sweeps = _batched_sweeps(sys, scorer, avail, assign, M,
                                        counts, rb_costs, allow_moves,
                                        max_sweeps, tele)
    else:
        def try_reassign(k: int, n_from: int, n_to: int, j: Optional[int]):
            """Cost delta of moving k from n_from to n_to (swapping with j)."""
            m_from = members[n_from][members[n_from] != k]
            m_to = members[n_to]
            if j is not None:
                m_to = m_to[m_to != j]
                m_from = np.append(m_from, j)
            m_to = np.append(m_to, k)
            c_from = scorer.rb_cost(n_from, m_from)
            c_to = scorer.rb_cost(n_to, m_to)
            new = c_from + c_to
            old = rb_costs[n_from] + rb_costs[n_to]
            return new - old, (m_from, m_to, c_from, c_to)

        swaps = 0
        sweeps = 0
        improved = True
        while improved and sweeps < max_sweeps:
            improved = False
            sweeps += 1
            # one child span per sweep: a regression in sweep count (or one
            # pathologically slow sweep) is attributable from the trace
            sweep_span = tele.span("matching.sweep", sweep=sweeps)
            sweep_span.__enter__()
            for u in avail:
                if assign[u] < 0:
                    continue
                # pairwise swaps (the paper's swap operation)
                for k in avail:
                    if k <= u or assign[k] < 0 or assign[k] == assign[u]:
                        continue
                    d, upd = try_reassign(u, assign[u], assign[k], k)
                    if d < -1e-12:
                        n_u, n_k = assign[u], assign[k]
                        members[n_u], members[n_k] = upd[0], upd[1]
                        rb_costs[n_u], rb_costs[n_k] = upd[2], upd[3]
                        assign[u], assign[k] = n_k, n_u
                        swaps += 1
                        improved = True
                # open-slot moves (housing-model open houses)
                if allow_moves:
                    for n in range(N):
                        if n == assign[u] or members[n].size >= Q:
                            continue
                        d, upd = try_reassign(u, assign[u], n, None)
                        if d < -1e-12:
                            n_u = assign[u]
                            members[n_u], members[n] = upd[0], upd[1]
                            rb_costs[n_u], rb_costs[n] = upd[2], upd[3]
                            assign[u] = n
                            swaps += 1
                            improved = True
            sweep_span.__exit__(None, None, None)

    rho = np.zeros((K, N), np.float32)
    matched = assign >= 0
    rho[np.flatnonzero(matched), assign[matched]] = 1.0
    stage.__exit__(None, None, None)

    # final powers under the chosen evaluator's assignment
    import jax.numpy as jnp
    with tele.stage("power"):
        p, cost, ok = power_mod.allocate_power(
            sys, jnp.asarray(rho), jnp.asarray(h, np.float32),
            jnp.asarray(alpha, np.float32), method="closed_form",
            telemetry=tele)
        p = tele.block(p)
    all_matched = bool(np.all(assign[avail] >= 0)) if avail.size else True
    feasible = ok and all_matched and np.isfinite(cost)
    unmatched_ids = (avail[assign[avail] < 0] if avail.size
                     else np.zeros(0, np.int64))
    unmatched = int(unmatched_ids.size)
    tele.solver("matching", swaps=swaps, sweeps=sweeps,
                rb_evals=scorer.evals, unmatched=unmatched,
                feasible=bool(feasible), mode=mode_used)
    if unmatched:
        tele.fault("partial_matching", injected=False,
                   unmatched=[int(k) for k in unmatched_ids])
    reg = metrics_mod.get_default()
    if reg.enabled:
        reg.counter("feel_matching_calls_total",
                    "swap-matching (Alg. 2) invocations").inc()
        reg.counter("feel_matching_swaps_total",
                    "accepted swap/move operations").inc(swaps)
        reg.counter("feel_matching_sweeps_total",
                    "swap sweeps over available devices").inc(sweeps)
        reg.counter("feel_matching_rb_evals_total",
                    "candidate per-RB power evaluations").inc(scorer.evals)
        reg.counter("feel_matching_unmatched_total",
                    "available devices left without an RB").inc(unmatched)
        if not feasible:
            reg.counter("feel_solver_infeasible_total",
                        "infeasible solver outcomes by solver").inc(
                            1, solver="matching")
    return MatchingResult(assign=assign, rho=rho, p=np.asarray(p),
                          cost=cost, swaps=swaps, sweeps=sweeps,
                          feasible=feasible, unmatched=unmatched_ids,
                          mode=mode_used)
