"""The paper's contribution: joint resource allocation + data selection
for federated edge learning (FEEL), implemented in JAX.

Public surface:
  * SystemParams / RoundState / default_system / sample_round
  * channel: NOMA + SIC rates and feasibility
  * cost: energy / reward / net-cost model (eqs. 7-18)
  * delta: convergence-gap objective (eqs. 22/26)
  * power: Algorithm 3 (CCP) + exact closed form
  * matching: Algorithm 2 (swap matching)
  * selection: Algorithms 4-5 + exact oracle
  * joint: Algorithm 1 + baselines 1-4
  * convergence: Lemmas 1-3 made executable
"""
from . import channel, convergence, cost, delta, joint, matching, power, selection  # noqa: F401
from .joint import RoundDecision, baseline_scheme, proposed_scheme  # noqa: F401
from .types import RoundState, SystemParams, default_system, sample_round  # noqa: F401
