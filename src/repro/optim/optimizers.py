"""Optimizers as pure pytree transformations.

Implemented: sgd, momentum, adam, adamw, adafactor (factored second
moment — the only optimizer whose state fits HBM for the 671B MoE
config), plus chain / clip_by_global_norm / scale_by_schedule
combinators.  All states are pytrees of arrays so they shard exactly
like the parameters they track (crucial for the dry-run memory
analysis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class GradientTransformation:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]],
                     Tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# ------------------------------------------------------------------ basic

def sgd(lr: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return GradientTransformation(init, update)


def momentum(lr: float, beta: float = 0.9,
             nesterov: bool = False) -> GradientTransformation:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        new_m = jax.tree.map(lambda m, g: beta * m + g, state, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -lr * (beta * m + g),
                               new_m, grads)
        else:
            upd = jax.tree.map(lambda m: -lr * m, new_m)
        return upd, new_m

    return GradientTransformation(init, update)


# ------------------------------------------------------------------- adam

class AdamState(NamedTuple):
    count: Array
    mu: PyTree
    nu: PyTree


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         state_dtype: Any = jnp.float32) -> GradientTransformation:
    """Adam / AdamW (decoupled decay when weight_decay > 0)."""

    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=state_dtype)
        return AdamState(count=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(z, params),
                         nu=jax.tree.map(z, params))

    def update(grads, state, params=None):
        count = state.count + 1
        cast = lambda g: g.astype(state_dtype)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * cast(g),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * cast(g) ** 2,
                          state.nu, grads)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m, v, p):
            step = m / bc1 / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                step = step + weight_decay * p.astype(state_dtype)
            return (-lr * step)

        if params is None:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        else:
            updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init, update)


def adamw(lr: float, weight_decay: float = 0.01,
          **kw) -> GradientTransformation:
    return adam(lr, weight_decay=weight_decay, **kw)


# -------------------------------------------------------------- adafactor

class AdafactorState(NamedTuple):
    count: Array
    vr: PyTree  # row second-moment (or full v for <2D leaves)
    vc: PyTree  # col second-moment (dummy for <2D leaves)


def adafactor(lr: float, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8) -> GradientTransformation:
    """Factored second-moment estimator (Shazeer & Stern, 2018).

    State per (.., R, C) matrix is R + C floats instead of R*C — the
    memory term that lets 100B+ parameter configs fit a v5e pod.
    Factoring applies to the trailing two dims of >=2-D leaves.
    """

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vr_init(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros_like(p, dtype=jnp.float32))

        def vc_init(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((), jnp.float32))

        return AdafactorState(count=jnp.zeros((), jnp.int32),
                              vr=jax.tree.map(vr_init, params),
                              vc=jax.tree.map(vc_init, params))

    def update(grads, state, params=None):
        count = state.count + 1
        beta = 1.0 - (count.astype(jnp.float32)) ** (-decay)

        def upd(g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(g):
                new_vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                new_vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(new_vr, axis=-1, keepdims=True),
                                    eps)
                v_est = (new_vr[..., :, None] * new_vc[..., None, :] /
                         denom[..., None])
                step = g / jnp.sqrt(v_est + eps)
            else:
                new_vr = beta * vr + (1 - beta) * g2
                new_vc = vc
                step = g / jnp.sqrt(new_vr + eps)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(step * step) + eps)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            return -lr * step, new_vr, new_vc

        flat_g, treedef = jax.tree.flatten(grads)
        flat_vr = treedef.flatten_up_to(state.vr)
        flat_vc = treedef.flatten_up_to(state.vc)
        out = [upd(g, vr, vc) for g, vr, vc in zip(flat_g, flat_vr, flat_vc)]
        updates = treedef.unflatten([o[0] for o in out])
        new_vr = treedef.unflatten([o[1] for o in out])
        new_vc = treedef.unflatten([o[2] for o in out])
        return updates, AdafactorState(count=count, vr=new_vr, vc=new_vc)

    return GradientTransformation(init, update)


# ------------------------------------------------------------ combinators

def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params=None):
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        return jax.tree.map(lambda g: g * scale, grads), state

    return GradientTransformation(init, update)


def scale_by_schedule(schedule: Callable[[Array], Array]
                      ) -> GradientTransformation:
    def init(params):
        return jnp.zeros((), jnp.int32)

    def update(grads, state, params=None):
        scale = schedule(state)
        return jax.tree.map(lambda g: g * scale, grads), state + 1

    return GradientTransformation(init, update)
