"""Minimal optimizer library (no optax in this environment).

GradientTransformation-style API:
    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from .optimizers import (GradientTransformation, adafactor, adam, adamw,
                         apply_updates, chain, clip_by_global_norm,
                         global_norm, momentum, scale_by_schedule, sgd)
from .schedules import constant_schedule, cosine_schedule, warmup_cosine

__all__ = [
    "GradientTransformation", "adam", "adamw", "adafactor", "sgd",
    "momentum", "chain", "clip_by_global_norm", "apply_updates",
    "global_norm", "scale_by_schedule", "constant_schedule",
    "cosine_schedule", "warmup_cosine",
]
