"""Production meshes.

Single pod: (16, 16) = ("data", "model") — 256 TPU v5e chips.
Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips.

Defined as a FUNCTION so importing this module never touches jax
device state (the dry-run launcher must set XLA_FLAGS before any jax
initialization).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older jax without the devices kwarg
        import numpy as np
        return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape),
                                 axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh for CPU integration tests (honors available devices)."""
    import numpy as np
    devs = np.asarray(jax.devices()[:data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """The batch-sharding axes: ("pod","data") multi-pod, else ("data",)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def data_size(mesh: jax.sharding.Mesh) -> int:
    return math.prod(mesh.shape[a] for a in data_axes(mesh))


def model_size(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape.get("model", 1)
