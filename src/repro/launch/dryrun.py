import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x shape) on the
production meshes, extract cost/memory/collective analyses, and append
one JSON record per combination to experiments/dryrun.jsonl.

Usage:
    python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
    python -m repro.launch.dryrun --all                 # single-pod sweep
    python -m repro.launch.dryrun --all --multi-pod     # 512-chip sweep

The XLA_FLAGS line above MUST stay the first statement: jax locks the
device count on first init.  Nothing else in the repo sets it.
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict

import jax

from ..configs import ARCHS
from . import mesh as mesh_mod
from . import sharding as sh
from .shapes import SHAPES, applicable, build_spec

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "f16": 2, "bf16": 2, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "f8e4m3": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, by op kind.

    Token-search based: the defining line looks like
        %name = SHAPE op-name(...)   or   ... op-name-start(...)
    (a regex with a greedy shape class backtracks "all-reduce" into
    "-reduce" and silently drops single-output collectives — found the
    hard way; the async "-done" retrievals are intentionally skipped
    so started collectives aren't double-counted)."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        _, _, rhs = line.partition(" = ")
        rhs = " " + rhs  # shape may start the segment
        for c in _COLLECTIVES:
            pos = rhs.find(f" {c}(")
            if pos < 0:
                pos = rhs.find(f" {c}-start(")
            if pos >= 0:
                out[c] += _shape_bytes(rhs[:pos])
                out["count"] += 1
                break
    return out


def _compile_metrics(spec) -> dict:
    """Lower + compile one spec; return raw per-device metrics."""
    donate = {"train": (0, 1), "prefill": (), "decode": (1,)}[spec.kind]
    t0 = time.time()
    lowered = jax.jit(spec.step_fn, donate_argnums=donate).lower(*spec.args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_rec = {"error": str(e)}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "memory": mem_rec,
        "hlo_lines": hlo.count("\n"),
        "t_lower": t_lower,
        "t_compile": t_compile,
    }


def run_one(arch: str, shape: str, multi_pod: bool, feel: bool = True,
            mla_absorbed: bool = False, variant: str = "baseline",
            out_path: str = "experiments/dryrun.jsonl",
            cfg_overrides: dict | None = None,
            strategy: str = "tp") -> dict:
    """Lower + compile (arch x shape) on the production mesh.

    cost_analysis counts a lax.scan body ONCE regardless of trip count,
    so we compile at scan_unroll=1 and scan_unroll=2 and extrapolate
    the affine law F(u) = outside + u*body to the true layer count
    (validated within 0.4% FLOPs / 4% bytes of a full unroll on
    llama3.2-3b; the scan program is also what production executes).
    """
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape,
           "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
           "multi_pod": multi_pod, "variant": variant, "feel": feel,
           "mla_absorbed": mla_absorbed, "strategy": strategy, "ok": False}
    t0 = time.time()
    try:
        from ..models.transformer import _layer_plan
        spec1 = build_spec(arch, shape, mesh, feel=feel,
                           mla_absorbed=mla_absorbed, scan_unroll=1,
                           cfg_overrides=cfg_overrides, strategy=strategy)
        _, n_body, _, _ = _layer_plan(spec1.cfg)
        with mesh, sh.with_mesh_constraints(mesh, strategy):
            m1 = _compile_metrics(spec1)
            if n_body >= 2:
                spec2 = build_spec(arch, shape, mesh, feel=feel,
                                   mla_absorbed=mla_absorbed,
                                   scan_unroll=2,
                                   cfg_overrides=cfg_overrides,
                                   strategy=strategy)
                m2 = _compile_metrics(spec2)
            else:
                m2 = None

        def extrap(v1, v2):
            if m2 is None:
                return v1
            body = max(v2 - v1, 0.0)
            return max(v1 - body, 0.0) + n_body * body

        flops = extrap(m1["flops"], m2["flops"] if m2 else 0.0)
        bytes_acc = extrap(m1["bytes"], m2["bytes"] if m2 else 0.0)
        coll = {c: int(extrap(m1["coll"][c], m2["coll"][c] if m2 else 0))
                for c in _COLLECTIVES}
        coll["count"] = m1["coll"]["count"]
        coll_total = sum(coll[c] for c in _COLLECTIVES)
        rec.update(
            ok=True, n_body=n_body,
            t_lower_s=round(m1["t_lower"], 2),
            t_compile_s=round(m1["t_compile"]
                              + (m2["t_compile"] if m2 else 0.0), 2),
            flops_per_device=flops, bytes_per_device=bytes_acc,
            collective_bytes_per_device=coll_total,
            collectives=coll, memory=m1["memory"],
            raw_scan_flops=m1["flops"],
            hlo_lines=m1["hlo_lines"],
            compute_term_s=flops / PEAK_FLOPS,
            memory_term_s=bytes_acc / HBM_BW,
            collective_term_s=coll_total / ICI_BW,
        )
        terms = {"compute": rec["compute_term_s"],
                 "memory": rec["memory_term_s"],
                 "collective": rec["collective_term_s"]}
        rec["bottleneck"] = max(terms, key=terms.get)

        # MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active
        cfg = spec1.cfg
        import jax.tree_util as jtu
        total = active = 0
        for path, leaf in jtu.tree_flatten_with_path(spec1.args[0])[0]:
            keys = [str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path]
            n = 1
            for s in leaf.shape:
                n *= s
            total += n
            is_expert = (cfg.n_experts > 0 and leaf.ndim >= 3
                         and cfg.n_experts in leaf.shape
                         and keys[-1] in ("w_gate", "w_up", "w_down")
                         and "shared" not in keys)
            active += int(n * cfg.topk / cfg.n_experts) if is_expert else n
        info = SHAPES[shape]
        D = info["batch"] * (info["seq"] if spec1.kind != "decode" else 1)
        mult = 6 if spec1.kind == "train" else 2
        model_flops = mult * active * D / mesh.size
        rec.update(params_total=int(total), params_active=int(active),
                   model_flops_per_device=model_flops,
                   useful_ratio=(model_flops / flops) if flops else None)
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["t_total_s"] = round(time.time() - t0, 2)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "a") as f:
        json.dump(rec, f)
        f.write("\n")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS + ["all"], default=None)
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-feel", action="store_true")
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    args = ap.parse_args()

    archs = ARCHS if (args.all or args.arch in (None, "all")) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape in (None, "all")) \
        else [args.shape]

    for arch in archs:
        for shape in shapes:
            if not applicable(arch, shape):
                print(f"SKIP  {arch} x {shape} (sub-quadratic gate, "
                      "see DESIGN.md)")
                continue
            rec = run_one(arch, shape, args.multi_pod,
                          feel=not args.no_feel,
                          mla_absorbed=args.mla_absorbed,
                          variant=args.variant, out_path=args.out,
                          strategy=args.strategy)
            status = "OK  " if rec["ok"] else "FAIL"
            extra = (f"flops/dev={rec.get('flops_per_device', 0):.3g} "
                     f"bottleneck={rec.get('bottleneck')}"
                     if rec["ok"] else rec.get("error", ""))
            print(f"{status} {arch:>20s} x {shape:<12s} mesh={rec['mesh']} "
                  f"t={rec['t_total_s']}s {extra}", flush=True)


if __name__ == "__main__":
    main()
