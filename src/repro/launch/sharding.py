"""Sharding rules: parameters, optimizer state, caches, batches,
and the activation constrainer installed around jitted steps.

Heuristic (DESIGN.md §5): for every array leaf
  * the largest dim divisible by the mesh "model" size shards over
    "model" (ties -> the later dim, i.e. the output features);
  * the largest *remaining* dim divisible by the total data size
    shards over the data axes (ZeRO/FSDP-style weight sharding, which
    is what lets the 236B/671B optimizer state fit HBM);
  * leading scan-stack dims (decoder "body") and dims < 128 never
    shard.
MoE expert tensors (E, d, f) are special-cased to expert parallelism:
E over (data x model) jointly when divisible (1 expert/chip — §Perf
pair B iter 2), else E -> "model" with the per-expert features ZeRO'd
over data.
"""
from __future__ import annotations

import math
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ArchConfig
from ..models.shard_ctx import use_constrainer
from . import mesh as mesh_mod

_MIN_SHARD_DIM = 128

# §Perf pair B iteration 2: joint (data x model) expert sharding.
# True = optimized default; set False to reproduce the pre-B2 baseline
# (E over model only, per-expert features ZeRO'd over data).
EXPERT_JOINT = True

# megatron pairing: these weights contract over their model-sharded dim
# (row-parallel -> one all-reduce of the block output over "model");
# everything else shards its OUT-features (column-parallel).
_ROW_PARALLEL = {"wo", "w_down", "out_proj", "w_out", "w_o"}


def _param_spec(name: str, shape, *, model: int, data: int, data_ax,
                skip_leading: bool, is_expert: bool) -> P:
    nd = len(shape)
    spec: list = [None] * nd
    start = 1 if (skip_leading and nd >= 3) else 0
    if nd - start < 2:
        return P(*spec)  # norms/biases: replicate

    if is_expert:
        # expert parallelism.  Preferred: E over data+model jointly
        # (1 expert/chip for E=256) — keeps every per-expert matmul
        # contraction unsharded, so no partial-sum all-reduces of the
        # (E, C, d) dispatch tensors (measured 4.1 TB/step when the
        # per-expert f dim was data-sharded; §Perf pair B).
        e_dim = start
        joint = data * model
        if EXPERT_JOINT and shape[e_dim] % joint == 0:
            spec[e_dim] = tuple(data_ax) + ("model",)
            return P(*spec)
        # fallback (E=160): E over model, ZeRO f over data
        if shape[e_dim] % model == 0:
            spec[e_dim] = "model"
        last = nd - 1
        if shape[last] % data == 0 and shape[last] >= _MIN_SHARD_DIM:
            spec[last] = data_ax
        return P(*spec)

    if name == "embed":
        # vocab-parallel table: the lookup is a gather, and a joint-
        # sharded feature dim forces SPMD into full rematerialization.
        v_dim = nd - 2  # (V, d) or (C, V, d)
        if shape[v_dim] % model == 0 and shape[v_dim] >= model:
            spec[v_dim] = "model"
        if shape[nd - 1] % data == 0 and shape[nd - 1] >= _MIN_SHARD_DIM:
            spec[nd - 1] = data_ax
        return P(*spec)

    m_dim = start if name in _ROW_PARALLEL else nd - 1
    if shape[m_dim] % model == 0 and shape[m_dim] >= model:
        spec[m_dim] = "model"
    # ZeRO data-sharding ONLY on non-contraction dims: row-parallel
    # weights contract over m_dim, so their output dim can carry the
    # data axes (XLA gathers the weight over data — cheap).  Column-
    # parallel weights contract over dim0; data-sharding it makes XLA
    # all-reduce activations over data (measured 75 GB/step on
    # llama3.2-3b), and joint (data+model) feature sharding makes SPMD
    # replicate the batch (measured 8x FLOPs) — both rejected, see
    # EXPERIMENTS.md §Perf iteration log.
    if name in _ROW_PARALLEL:
        out_dim = nd - 1
        if spec[out_dim] is None and shape[out_dim] % data == 0 \
                and shape[out_dim] >= _MIN_SHARD_DIM:
            spec[out_dim] = data_ax
    return P(*spec)


def param_shardings(mesh: Mesh, abstract_params: Any,
                    cfg: Optional[ArchConfig] = None) -> Any:
    """NamedSharding tree matching an eval_shape'd param tree."""
    model = mesh_mod.model_size(mesh)
    data = mesh_mod.data_size(mesh)
    data_ax = mesh_mod.data_axes(mesh)
    n_exp = cfg.n_experts if cfg is not None else 0

    def one(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        pstr = "/".join(keys)
        name = keys[-1] if keys else ""
        under_body = "body" in pstr
        is_expert = (n_exp > 0 and leaf.ndim >= 3 and "shared" not in pstr
                     and n_exp in leaf.shape
                     and name in ("w_gate", "w_up", "w_down"))
        spec = _param_spec(name, leaf.shape, model=model, data=data,
                           data_ax=data_ax, skip_leading=under_body,
                           is_expert=is_expert)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def cache_shardings(mesh: Mesh, abstract_cache: Any, batch: int) -> Any:
    """KV/state caches: batch dim over data axes when divisible; else
    the sequence dim (long_500k); heads/latent dims over model when
    divisible."""
    model = mesh_mod.model_size(mesh)
    data = mesh_mod.data_size(mesh)
    data_ax = mesh_mod.data_axes(mesh)

    def one(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        skip = nd >= 3 and "body" in "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        start = 1 if skip else 0
        spec: list = [None] * nd
        b_dim = start  # batch is always the first real dim
        rest = list(range(start + 1, nd))
        if shape[b_dim] % data == 0 and shape[b_dim] >= data:
            spec[b_dim] = data_ax
        elif rest and shape[rest[0]] % data == 0 \
                and shape[rest[0]] >= _MIN_SHARD_DIM:
            spec[rest[0]] = data_ax  # sequence-sharded cache
            rest = rest[1:]
        cand = [d for d in rest if shape[d] % model == 0
                and shape[d] >= model]
        if cand:
            spec[max(cand, key=lambda d: (shape[d], d))] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, abstract_cache)


def batch_shardings(mesh: Mesh, abstract_batch: Any,
                    strategy: str = "tp") -> Any:
    """strategy "tp": batch over the data axes (megatron hybrid).
    strategy "fsdp": batch over data+model jointly — every chip is a
    data shard; weights stay model-sharded and XLA all-gathers them
    per use (ZeRO-3 semantics)."""
    data_ax = mesh_mod.data_axes(mesh)
    data = mesh_mod.data_size(mesh)
    model = mesh_mod.model_size(mesh)
    batch_ax = tuple(data_ax) + (("model",) if strategy == "fsdp" else ())
    batch_div = data * (model if strategy == "fsdp" else 1)

    def one(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if leaf.ndim == 0 or name in ("alpha", "cache_index"):
            return NamedSharding(mesh, P())
        spec: list = [None] * leaf.ndim
        if leaf.shape[0] % batch_div == 0 and leaf.shape[0] >= batch_div:
            spec[0] = batch_ax
        elif leaf.shape[0] % data == 0 and leaf.shape[0] >= data:
            spec[0] = data_ax
        if strategy == "tp" and name == "embeds" \
                and leaf.shape[-1] % model == 0:
            spec[-1] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, abstract_batch)


# ---------------------------------------------------------- activations

def activation_constrainer(mesh: Mesh, strategy: str = "tp"):
    """Constrainer for repro.models.shard_ctx logical names."""
    data_ax = mesh_mod.data_axes(mesh)
    model = mesh_mod.model_size(mesh)
    data = mesh_mod.data_size(mesh)
    if strategy == "fsdp":
        data_ax = tuple(data_ax) + ("model",)
        data = data * model
        # activations carry no feature sharding under FSDP: make the
        # "divisible by model" checks always fail
        model = 1 << 62

    def build_spec(name, s):
        nd = len(s)
        spec: list = [None] * nd
        if name == "moe_ecd":
            # mirror the expert-weight sharding on the dispatch tensors
            if EXPERT_JOINT and s[0] % (data * model) == 0 \
                    and model > 1:
                spec[0] = tuple(data_ax) + ("model",)
            elif s[0] % model == 0:
                spec[0] = "model"
            return spec
        # batch-leading activations
        if s[0] % data == 0 and s[0] >= data:
            spec[0] = data_ax
        if name == "act_btd":
            return spec
        if name in ("act_btf", "logits_btv"):
            if s[-1] % model == 0 and s[-1] >= model:
                spec[-1] = "model"
            return spec
        if name == "act_bthd" and nd >= 3:
            if s[-2] % model == 0 and s[-2] >= model:
                spec[-2] = "model"
            return spec
        if name == "kv_cache" and nd >= 3:
            if spec[0] is None and s[1] % data == 0 \
                    and s[1] >= _MIN_SHARD_DIM:
                spec[1] = data_ax  # sequence-sharded cache (long_500k)
            if s[2] % model == 0 and s[2] >= model:
                spec[2] = "model"
            return spec
        return spec

    def constrain(x, name):
        if x.ndim < 2:
            return x
        spec = build_spec(name, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    return constrain


def with_mesh_constraints(mesh: Mesh, strategy: str = "tp"):
    """Context manager installing the activation constrainer."""
    return use_constrainer(activation_constrainer(mesh, strategy))
