"""Batched serving driver: prefill a batch of prompts, then greedy
decode with the KV cache — the ``serve_step`` the decode dry-run
shapes lower.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \
        --smoke --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, smoke_config
from ..models import (init_model, make_cache, make_decode_step,
                      make_prefill_step, param_count)


def serve(arch: str, batch: int, prompt_len: int, new_tokens: int,
          smoke: bool = True, seed: int = 0, mla_absorbed: bool = False):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg)
    print(f"arch={cfg.name} params={param_count(params):,}")

    max_len = prompt_len + new_tokens
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg, mla_absorbed=mla_absorbed),
                     donate_argnums=(1,))

    if cfg.modality == "text":
        prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
        b = {"tokens": prompts}
    elif cfg.modality == "vlm":
        b = {"embeds": jax.random.normal(
                 key, (batch, prompt_len, cfg.d_model), cfg.act_dtype),
             "positions": jnp.broadcast_to(
                 jnp.arange(prompt_len)[None, None, :],
                 (batch, 3, prompt_len)).astype(jnp.int32)}
    else:
        b = {"tokens": jax.random.randint(
            key, (batch, cfg.n_codebooks, prompt_len), 0, cfg.vocab)}

    t0 = time.time()
    logits, cache = prefill(params, b)
    # grow the cache to max_len (prefill built a prompt_len cache)
    full = make_cache(cfg, batch, max_len)

    def graft(dst, src):
        if dst.shape == src.shape:
            return src
        pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src, pad).astype(dst.dtype)

    cache = jax.tree.map(graft, full, cache)
    t_prefill = time.time() - t0

    def next_tok(lg):
        # text/vlm: (B,1,V) -> (B,); audio: (B,1,C,V) -> (B,C)
        return jnp.argmax(lg[:, -1], axis=-1)

    outs = []
    tok = next_tok(logits)  # greedy
    t0 = time.time()
    for i in range(new_tokens):
        idx = jnp.int32(prompt_len + i)
        if cfg.modality == "text":
            db = {"tokens": tok.reshape(batch, 1), "cache_index": idx}
        elif cfg.modality == "vlm":
            # continuation tokens have no patch embeds: feed zeros +
            # text positions (M-RoPE degenerates to 1-D for text)
            db = {"embeds": jnp.zeros((batch, 1, cfg.d_model),
                                      cfg.act_dtype),
                  "positions": jnp.full((batch, 3, 1), prompt_len + i,
                                        jnp.int32),
                  "cache_index": idx}
        else:
            db = {"tokens": tok[:, :, None].astype(jnp.int32),
                  "cache_index": idx}
        logits, cache = decode(params, cache, db)
        tok = next_tok(logits)
        outs.append(np.asarray(tok))
    t_decode = time.time() - t0
    print(f"prefill {prompt_len} toks x{batch}: {t_prefill:.2f}s; "
          f"decode {new_tokens} steps: {t_decode:.2f}s "
          f"({t_decode / max(new_tokens, 1) * 1e3:.0f} ms/step)")
    return outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (TPU pods)")
    ap.add_argument("--mla-absorbed", action="store_true")
    args = ap.parse_args()
    serve(args.arch, args.batch, args.prompt_len, args.new_tokens,
          smoke=not args.full, mla_absorbed=args.mla_absorbed)


if __name__ == "__main__":
    main()
