"""Training driver.

Runs real steps (allocating parameters) for any --arch at any scale
that fits the host; on TPU pods, pair with make_production_mesh.  The
FEEL integration (per-sample sigma scoring + exact Problem-4 selection
+ eq.-(19) IPW aggregation across the client/data axis) is on by
default — this is the paper's technique applied to LM training.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --smoke --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, smoke_config
from ..data.synthetic import synthetic_lm_batch
from ..models import FeelIntegration, init_model, make_train_step, param_count
from .shapes import make_optimizer


def synth_batch(cfg, key, batch, seq, n_clients, feel, eps=0.8):
    if cfg.modality == "text":
        b = synthetic_lm_batch(key, batch, seq, cfg.vocab)
    elif cfg.modality == "vlm":
        k1, k2 = jax.random.split(key)
        b = {"embeds": jax.random.normal(k1, (batch, seq, cfg.d_model),
                                         cfg.act_dtype),
             "positions": jnp.broadcast_to(
                 jnp.arange(seq)[None, None, :],
                 (batch, 3, seq)).astype(jnp.int32),
             "labels": jax.random.randint(k2, (batch, seq), 0, cfg.vocab)}
    else:
        k1, = jax.random.split(key, 1)
        t = jax.random.randint(k1, (batch, cfg.n_codebooks, seq + 1),
                               0, cfg.vocab)
        b = {"tokens": t[..., :-1], "labels": t[..., 1:]}
    if feel:
        ka = jax.random.fold_in(key, 7)
        b["alpha"] = (jax.random.uniform(ka, (n_clients,)) < eps
                      ).astype(jnp.float32)
    return b


def run(arch: str, steps: int, batch: int, seq: int, smoke: bool,
        feel: bool = True, n_clients: int = 4, log_every: int = 5,
        seed: int = 0):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg)
    print(f"arch={cfg.name} params={param_count(params):,} feel={feel}")
    opt = make_optimizer(cfg)
    opt_state = opt.init(params)
    feel_cfg = FeelIntegration(n_clients=n_clients) if feel else None
    step_fn = jax.jit(make_train_step(cfg, opt, feel=feel_cfg),
                      donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    for i in range(steps):
        b = synth_batch(cfg, jax.random.fold_in(key, 1000 + i), batch, seq,
                        n_clients, feel)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"sel={float(metrics['selected_frac']):.3f} "
                  f"t={time.time() - t0:.1f}s", flush=True)
    assert np.isfinite(losses[-1]), "training diverged"
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--no-feel", action="store_true")
    ap.add_argument("--clients", type=int, default=4)
    args = ap.parse_args()
    run(args.arch, args.steps, args.batch, args.seq, args.smoke,
        feel=not args.no_feel, n_clients=args.clients)


if __name__ == "__main__":
    main()
