"""Distribution & launch layer.

NOTE: ``dryrun`` must be imported/run as the entry module
(``python -m repro.launch.dryrun``) so its XLA_FLAGS line executes
before jax initializes devices; do not import it from here.
"""
from .mesh import (data_axes, data_size, make_host_mesh,
                   make_production_mesh, model_size)

__all__ = ["make_production_mesh", "make_host_mesh", "data_axes",
           "data_size", "model_size"]
