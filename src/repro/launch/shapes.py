"""Assigned input shapes and abstract input specs (ShapeDtypeStruct,
no allocation) for every (architecture x shape) dry-run combination."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import optim
from ..configs import get_config
from ..models import (FeelIntegration, init_model, make_cache,
                      make_decode_step, make_prefill_step, make_train_step)
from ..models.config import ArchConfig
from . import mesh as mesh_mod
from . import sharding as sh

SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k needs sub-quadratic context handling (DESIGN.md §3):
LONG_OK = {"falcon-mamba-7b", "recurrentgemma-9b", "gemma3-12b"}


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_OK
    return True


def make_optimizer(cfg: ArchConfig):
    builder = {"adamw": functools.partial(optim.adamw, weight_decay=0.01),
               "adam": optim.adam, "adafactor": optim.adafactor,
               "sgd": optim.sgd, "momentum": optim.momentum}[cfg.optimizer]
    return builder(cfg.learning_rate)


def _abstract_batch(cfg: ArchConfig, kind: str, B: int, S: int,
                    n_clients: int, feel: bool) -> Dict[str, Any]:
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if kind in ("train", "prefill"):
        if cfg.modality == "text":
            b = {"tokens": sds((B, S), i32)}
        elif cfg.modality == "vlm":
            b = {"embeds": sds((B, S, cfg.d_model), cfg.act_dtype),
                 "positions": sds((B, 3, S), i32)}
        else:
            b = {"tokens": sds((B, cfg.n_codebooks, S), i32)}
        if kind == "train":
            lab_shape = ((B, cfg.n_codebooks, S)
                         if cfg.modality == "audio" else (B, S))
            b["labels"] = sds(lab_shape, i32)
            if feel:
                b["alpha"] = sds((n_clients,), jnp.float32)
        return b
    # decode: one token
    if cfg.modality == "text":
        b = {"tokens": sds((B, 1), i32)}
    elif cfg.modality == "vlm":
        b = {"embeds": sds((B, 1, cfg.d_model), cfg.act_dtype),
             "positions": sds((B, 3, 1), i32)}
    else:
        b = {"tokens": sds((B, cfg.n_codebooks, 1), i32)}
    b["cache_index"] = sds((), i32)
    return b


@dataclasses.dataclass
class DryRunSpec:
    """Everything needed to lower one (arch x shape) on a mesh."""
    arch: str
    shape: str
    kind: str
    step_fn: Any          # the function to jit
    args: Tuple[Any, ...]  # abstract args with shardings attached
    cfg: ArchConfig
    n_devices: int


def build_spec(arch: str, shape: str, mesh, *, feel: bool = True,
               mla_absorbed: bool = False, scan_unroll: int = 1,
               cfg_overrides: Optional[dict] = None,
               strategy: str = "tp") -> DryRunSpec:
    import dataclasses as _dc
    cfg = _dc.replace(get_config(arch), scan_unroll=scan_unroll,
                      **(cfg_overrides or {}))
    info = SHAPES[shape]
    kind, S, B = info["kind"], info["seq"], info["batch"]
    n_clients = mesh_mod.data_size(mesh)

    params_abs = jax.eval_shape(lambda k: init_model(k, cfg),
                                jax.random.PRNGKey(0))
    p_shard = sh.param_shardings(mesh, params_abs, cfg)
    params_in = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        params_abs, p_shard)

    batch_abs = _abstract_batch(cfg, kind, B, S, n_clients, feel)
    b_shard = sh.batch_shardings(mesh, batch_abs, strategy=strategy)
    batch_in = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        batch_abs, b_shard)

    if kind == "train":
        opt = make_optimizer(cfg)
        feel_cfg = (FeelIntegration(n_clients=n_clients)
                    if feel else None)
        step = make_train_step(cfg, opt, feel=feel_cfg)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_shard = sh.param_shardings(mesh, opt_abs, cfg)
        opt_in = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            opt_abs, o_shard)
        args = (params_in, opt_in, batch_in)
    elif kind == "prefill":
        step = make_prefill_step(cfg)
        args = (params_in, batch_in)
    else:
        step = make_decode_step(cfg, mla_absorbed=mla_absorbed)
        cache_abs = jax.eval_shape(
            lambda: make_cache(cfg, B, S, dtype=cfg.act_dtype))
        c_shard = sh.cache_shardings(mesh, cache_abs, B)
        cache_in = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            cache_abs, c_shard)
        args = (params_in, cache_in, batch_in)

    return DryRunSpec(arch=arch, shape=shape, kind=kind, step_fn=step,
                      args=args, cfg=cfg, n_devices=mesh.size)
