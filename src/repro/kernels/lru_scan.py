"""Blocked linear-recurrence scan kernel:  h_t = a_t * h_{t-1} + b_t.

Serves both recurrent mixers of the zoo (RG-LRU gates and the
diagonalized Mamba-1 recurrence, with the (d_inner, n_state) plane
flattened into channels).

Schedule: grid (batch, n_channel_blocks, n_seq_blocks) with the
sequence axis minor-most.  The carry h lives in VMEM scratch and
persists across the sequence sweep of each (batch, channel) block —
the cross-block dependency is the grid-carry, and inside a block the
recurrence runs as an unrolled-by-the-compiler fori over the (seq,
channel) VMEM tile.  One HBM read of a/b and one write of h per
element; VPU-only.

(The pure-jnp path uses jax.lax.associative_scan — log-depth but ~3x
the HBM traffic; this kernel is the linear-work alternative for real
TPUs.  Both validated against kernels/ref.py.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_SEQ = 256
DEFAULT_BLOCK_CH = 256


def _scan_kernel(a_ref, b_ref, o_ref, h_ref, *, block_seq: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)  # (block_seq, block_ch)
    b = b_ref[0].astype(jnp.float32)

    def body(t, carry):
        h, out = carry
        h = a[t] * h + b[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 0)
        return h, out

    h0 = h_ref[...]
    out0 = jnp.zeros_like(a)
    h_fin, out = jax.lax.fori_loop(0, block_seq, body, (h0, out0))
    h_ref[...] = h_fin
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_seq", "block_ch",
                                             "interpret"))
def lru_scan(a: jax.Array, b: jax.Array,
             block_seq: int = DEFAULT_BLOCK_SEQ,
             block_ch: int = DEFAULT_BLOCK_CH,
             interpret: bool = True) -> jax.Array:
    """a, b: (B, S, C) -> h: (B, S, C) with h_t = a_t h_{t-1} + b_t."""
    B, S, C = a.shape
    bs = min(block_seq, S)
    bc = min(block_ch, max(128, C))
    ns, nc = -(-S // bs), -(-C // bc)

    def pad(x):
        return jnp.pad(x, ((0, 0), (0, ns * bs - S), (0, nc * bc - C)))

    out = pl.pallas_call(
        functools.partial(_scan_kernel, block_seq=bs),
        grid=(B, nc, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bc), lambda b, c, s: (b, s, c)),
            pl.BlockSpec((1, bs, bc), lambda b, c, s: (b, s, c)),
        ],
        out_specs=pl.BlockSpec((1, bs, bc), lambda b, c, s: (b, s, c)),
        out_shape=jax.ShapeDtypeStruct((B, ns * bs, nc * bc), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bc,), jnp.float32)],
        interpret=interpret,
    )(pad(a), pad(b))
    return out[:, :S, :C]
