"""Causal flash attention as a Pallas TPU kernel.

Schedule: grid (batch*heads, n_q_blocks, n_k_blocks) with the k axis
minor-most, so the online-softmax accumulators (m, l, acc) live in VMEM
scratch and persist across the k sweep of each q block — the classic
flash schedule mapped to the TPU grid-carry idiom (no atomics, no
shared-memory tiles; the MXU consumes (block_q x d) @ (d x block_k)
tiles directly from VMEM).

Block sizes default to (128, 128): multiples of the (8, 128) VPU lanes
and the 128x128 MXU, and small enough that q/k/v/acc tiles fit VMEM
(~(2*128*d + 128*d + 128*128) * 4B << 16 MiB for d <= 256).

Fully-masked k blocks (block start beyond the causal diagonal) are
skipped with pl.when, so the causal sweep does ~half the work — this is
the optimization the paper-agnostic roofline pass credits attention
with (HLO cost_analysis of the jnp path counts the full rectangle).

Validated in interpret mode against kernels/ref.py on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, seq: int,
                  causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # last k block this q block needs (causal) — also the write step
    last_ki = jnp.minimum((q_start + block_q - 1) // block_k, nk - 1) \
        if causal else nk - 1

    @pl.when((k_start <= q_start + block_q - 1) | (not causal))
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = cols < seq  # key padding
        if causal:
            mask &= rows >= cols
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == last_ki)
    def _write():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jax.Array:
    """q, k, v: (BH, S, d) — batch and heads pre-merged, MHA layout.

    Sequences are padded to the block size internally; ``interpret``
    defaults to True because this container is CPU-only (set False on
    real TPUs).
    """
    BH, S, d = q.shape
    scale = d ** -0.5 if scale is None else scale
    nq = -(-S // block_q)
    nk = -(-S // block_k)
    Sp_q, Sp_k = nq * block_q, nk * block_k

    def padk(x, to):
        return jnp.pad(x, ((0, 0), (0, to - S), (0, 0)))

    qp, kp, vp = padk(q, Sp_q), padk(k, Sp_k), padk(v, Sp_k)
    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, seq=S, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),    # running max m
            pltpu.VMEM((block_q,), jnp.float32),    # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :S]
