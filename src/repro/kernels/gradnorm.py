"""Per-sample gradient-norm scoring kernel (the sigma_{k,j} producer).

For a linear head  logits = h W + b  with CE loss, the exact per-sample
gradient-norm^2 of the head is

    sigma_j = ||p_j - y_j||^2 * (||h_j||^2 + 1)

so the whole score reduces to two row-wise squared norms.  This kernel
computes row-wise sum-of-squares with feature-dim tiling: grid
(n_row_blocks, n_feat_blocks) with the feature axis minor-most and a
VMEM scratch accumulator carried across the feature sweep — one HBM
pass over the matrix, VPU-only (no MXU), (8, 128)-aligned tiles.

The fused wrapper ``gradnorm_sigma`` runs it over the feature matrix h
and the logit-residual matrix d and combines:
    sigma = (rownorm2(h) + 1) * rownorm2(d).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_FEAT = 512


def _rownorm2_kernel(x_ref, o_ref, acc_ref, *, n_feat: int,
                     block_feat: int):
    fi = pl.program_id(1)
    nf = pl.num_programs(1)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (block_rows, block_feat)
    # mask feature padding
    col = fi * block_feat + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(col < n_feat, x, 0.0)
    acc_ref[...] += jnp.sum(x * x, axis=1)

    @pl.when(fi == nf - 1)
    def _write():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_feat",
                                             "interpret"))
def rownorm2(x: jax.Array, block_rows: int = DEFAULT_BLOCK_ROWS,
             block_feat: int = DEFAULT_BLOCK_FEAT,
             interpret: bool = True) -> jax.Array:
    """sum(x^2, axis=-1) for x: (N, F) -> (N,) float32."""
    N, F = x.shape
    br = min(block_rows, max(8, N))
    bf = min(block_feat, max(128, F))
    nr, nf = -(-N // br), -(-F // bf)
    xp = jnp.pad(x, ((0, nr * br - N), (0, nf * bf - F)))
    out = pl.pallas_call(
        functools.partial(_rownorm2_kernel, n_feat=F, block_feat=bf),
        grid=(nr, nf),
        in_specs=[pl.BlockSpec((br, bf), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nr * br,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br,), jnp.float32)],
        interpret=interpret,
    )(xp)
    return out[:N]


def gradnorm_sigma(h: jax.Array, dlogits: jax.Array,
                   interpret: bool = True) -> jax.Array:
    """sigma = (||h||^2 + 1) * ||dlogits||^2 per row."""
    return (rownorm2(h, interpret=interpret) + 1.0) \
        * rownorm2(dlogits, interpret=interpret)
