"""Jit'd public wrappers around the Pallas kernels.

``use_pallas`` flags on model configs route hot paths through these on
real TPUs (interpret=False); the CPU container always validates with
interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .gradnorm import gradnorm_sigma as _sigma
from .gradnorm import rownorm2 as _rownorm2
from .lru_scan import lru_scan as _lru_scan


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool = True,
                         interpret: bool = True) -> jax.Array:
    """q,k,v: (B, S, H, d) MHA layout -> (B, S, H, d).

    GQA callers should broadcast kv heads first (the kernel is
    head-merged; the jnp zoo path stays GQA-native instead)."""
    B, S, H, d = q.shape
    fold = lambda x: jnp.moveaxis(x, 2, 1).reshape(B * H, S, d)
    out = _flash(fold(q), fold(k), fold(v), causal=causal,
                 interpret=interpret)
    return jnp.moveaxis(out.reshape(B, H, S, d), 1, 2)


rownorm2 = _rownorm2
gradnorm_sigma = _sigma
lru_scan = _lru_scan


def sigma_from_head(h: jax.Array, logits: jax.Array, labels: jax.Array,
                    interpret: bool = True) -> jax.Array:
    """Exact last-layer sigma from features + logits (fused path).

    h: (N, d) penultimate features; logits: (N, V); labels: (N,).
    """
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    y = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    return gradnorm_sigma(h, p - y, interpret=interpret)
