"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        scale: float | None = None) -> jax.Array:
    """q, k, v: (BH, S, d)."""
    d = q.shape[-1]
    scale = d ** -0.5 if scale is None else scale
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def rownorm2_ref(x: jax.Array) -> jax.Array:
    return jnp.sum(jnp.square(x.astype(jnp.float32)), axis=-1)


def gradnorm_sigma_ref(h: jax.Array, dlogits: jax.Array) -> jax.Array:
    return (rownorm2_ref(h) + 1.0) * rownorm2_ref(dlogits)


def lru_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Sequential-definition oracle: h_t = a_t h_{t-1} + b_t."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, h = jax.lax.scan(step, jnp.zeros_like(a[:, 0]),
                        (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(h, 0, 1)
