"""Checkpointing: pytree <-> .npz with path-encoded keys + JSON metadata.

Sharded restore: ``restore_sharded`` device_puts each leaf with the
sharding taken from an abstract target tree, so a checkpoint written on
one mesh can be loaded onto another (standard resharding-on-load).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_pytree(path: str, tree: Any, metadata: Optional[dict] = None):
    """Write atomically: a crash mid-write leaves either the previous
    complete checkpoint or none, never a truncated .npz — what makes
    periodic checkpointing crash-safe (``FEELTrainer.resume``)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    arrays, _ = _flatten(tree)
    dtypes = {}
    store = {}
    for key, arr in arrays.items():
        # numpy can't serialize bfloat16 (void dtype); view as uint16
        if arr.dtype == jnp.bfloat16:
            dtypes[key] = "bfloat16"
            arr = arr.view(np.uint16)
        store[key] = arr
    store["__dtypes__"] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8)
    final = path if path.endswith(".npz") else path + ".npz"
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **store)
    os.replace(tmp, final)
    if metadata is not None:
        tmp_meta = path + ".meta.json.tmp"
        with open(tmp_meta, "w") as f:
            json.dump(metadata, f, indent=2)
        os.replace(tmp_meta, path + ".meta.json")


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of ``like`` (names must match)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    dtypes = {}
    if "__dtypes__" in data:
        dtypes = json.loads(bytes(data["__dtypes__"]).decode())
    arrays, treedef = _flatten(like)
    leaves = []
    for key in arrays:
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if dtypes.get(key) == "bfloat16":
            arr = jnp.asarray(arr.view(np.uint16)).view(jnp.bfloat16)
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_sharded(path: str, abstract: Any) -> Any:
    """Load and device_put each leaf with the sharding of ``abstract``
    (a tree of jax.ShapeDtypeStruct with .sharding set)."""
    host = load_pytree(path, abstract)

    def put(x, ref):
        sharding = getattr(ref, "sharding", None)
        return jax.device_put(x, sharding) if sharding is not None else x

    return jax.tree.map(put, host, abstract)


def load_metadata(path: str) -> Optional[dict]:
    meta = path + ".meta.json"
    if os.path.exists(meta):
        with open(meta) as f:
            return json.load(f)
    return None
