from .checkpoint import (load_metadata, load_pytree, restore_sharded,
                         save_pytree)

__all__ = ["save_pytree", "load_pytree", "restore_sharded",
           "load_metadata"]
