"""Tests for the schema-v4 span layer: version round-trips, span-tree
reconstruction on a real trainer trace, and the three consumers
(export / diff / dash) end to end."""
import json
import types

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import default_system
from repro.data import SyntheticImages, non_iid_split
from repro.fed import FEELConfig, FEELTrainer, FaultSpec
from repro.models import cnn

from tests.test_obs import _tiny_trainer


# ----------------------------------------------------------- versioning

def _v1_records():
    """A hand-built pre-span trace (no span ids, no fault t_s)."""
    return [
        {"ev": "header", "v": 1, "meta": {"source": "synthetic-v1"}},
        {"ev": "stage", "v": 1, "round": 0, "stage": "matching",
         "t0_s": 0.0, "dur_s": 0.5},
        {"ev": "solver", "v": 1, "round": 0, "solver": "matching",
         "counters": {"swaps": 2}},
        {"ev": "round", "v": 1, "round": 0, "wall_s": 1.0,
         "net_cost": -0.5, "delta_obj": 2.0, "n_selected": 3,
         "n_uploaded": 2, "feasible": True, "test_acc": None},
    ]


def _bump(records, v):
    return [dict(r, v=v) for r in records]


@pytest.mark.parametrize("version", [1, 2, 3, 4])
def test_load_trace_roundtrips_all_schema_versions(tmp_path, version):
    records = _bump(_v1_records(), version)
    if version >= 2:
        records.append({"ev": "fault", "v": version, "round": 0,
                        "kind": "dropout", "injected": True, "device": 1,
                        "detail": {}})
    if version >= 4:
        records.append({"ev": "span", "v": 4, "round": 0,
                        "name": "matching.sweep", "span_id": 2,
                        "parent_id": 1, "t0_s": 0.1, "dur_s": 0.2,
                        "attrs": {"sweep": 1}})
    path = tmp_path / f"v{version}.jsonl"
    path.write_text("".join(json.dumps(r) + "\n" for r in records))

    loaded = obs.load_trace(str(path))
    assert loaded == records
    # every record parses without error under the v4 reader
    parsed = [obs.parse_record(r) for r in loaded]
    assert isinstance(parsed[1], obs.StageEvent)
    assert parsed[1].span_id is None  # legacy stages carry no span ids
    if version >= 2:
        fault = next(p for p in parsed if isinstance(p, obs.FaultEvent))
        assert fault.t_s is None  # pre-v4 faults carry no timestamp
    if version >= 4:
        span = next(p for p in parsed if isinstance(p, obs.SpanEvent))
        assert span.parent_id == 1 and span.attrs == {"sweep": 1}
        # SpanEvent round-trips byte-identically through to_record
        assert span.to_record() == records[-1]
    s = obs.summarize(loaded)
    assert s.n_rounds == 1 and s.stages["matching"].calls == 1


def test_reader_rejects_future_versions():
    with pytest.raises(ValueError):
        obs.parse_record({"ev": "span", "v": obs.SCHEMA_VERSION + 1,
                          "name": "x", "span_id": 1, "t0_s": 0.0,
                          "dur_s": 0.0})


# ----------------------------------------------------- tree construction

def test_span_nesting_and_parent_tracking(tmp_path):
    tele = obs.Telemetry(path=str(tmp_path / "t.jsonl"))
    tele.begin_round(0)
    with tele.stage("outer"):
        with tele.span("mid", device=3):
            with tele.span("leaf"):
                pass
        with tele.span("mid2"):
            pass
    tele.close()

    roots, orphans = obs.build_tree(obs.load_trace(str(tmp_path
                                                       / "t.jsonl")),
                                    strict=True)
    assert orphans == []
    (outer,) = roots
    assert outer.name == "outer" and outer.kind == "stage"
    assert [c.name for c in outer.children] == ["mid", "mid2"]
    (leaf,) = outer.children[0].children
    assert leaf.path() == "outer/mid/leaf"
    assert outer.children[0].attrs == {"device": 3}
    # self time never goes negative and children stay inside the parent
    for node in outer.walk():
        assert node.self_s() >= 0.0
        if node.parent is not None:
            assert node.t0_s >= node.parent.t0_s - 1e-9


def test_build_tree_strict_raises_on_orphans():
    records = [{"ev": "span", "v": 4, "round": 0, "name": "lost",
                "span_id": 7, "parent_id": 99, "t0_s": 0.0, "dur_s": 0.1,
                "attrs": {}}]
    roots, orphans = obs.build_tree(records)
    assert roots == [] and len(orphans) == 1
    with pytest.raises(ValueError, match="orphan"):
        obs.build_tree(records, strict=True)


def test_trainer_trace_builds_valid_tree(tmp_path):
    path = str(tmp_path / "train.jsonl")
    tele = obs.Telemetry(path=path)
    trainer = _tiny_trainer(telemetry=tele)
    trainer.run(2)
    tele.close()

    trace = obs.load_trace(path)
    roots, orphans = obs.build_tree(trace, strict=True)  # no orphans
    rounds = [r for r in roots if r.name == "round"]
    assert [r.round for r in rounds] == [0, 1]
    for r in rounds:
        child_names = [c.name for c in r.children]
        for required in obs.REQUIRED_STAGES:
            assert required in child_names
    # solver child spans hang under their stages, not under the round
    paths = obs.self_seconds_by_path(trace)
    assert "round/selection/selection.gp" in paths
    assert "round/selection/selection.recover" in paths
    assert "round/matching/matching.init" in paths
    assert all(v >= 0.0 for v in paths.values())


def test_stage_alias_keeps_metrics_histogram_working(tmp_path):
    reg = obs.Registry()
    obs.metrics.set_default(reg)
    tele = obs.Telemetry()
    tele.begin_round(0)
    with tele.stage("sigma"):
        pass
    obs.metrics.set_default(None)
    fam = [f for f in reg.snapshot()
           if f["name"] == "feel_stage_seconds"]
    assert fam, "stage() no longer feeds feel_stage_seconds"
    (st,) = [e for e in tele.events if isinstance(e, obs.StageEvent)]
    assert st.span_id is not None  # v4: stages carry span identity


# ------------------------------------------------------------- consumers

def test_export_chrome_trace_end_to_end(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tele = obs.Telemetry(path=path, meta={"source": "test"})
    trainer = _tiny_trainer(telemetry=tele)
    trainer.run(2)
    tele.close()

    out = str(tmp_path / "t.json")
    obj = obs.export_file(path, out)
    with open(out) as f:
        loaded = json.load(f)  # valid JSON on disk
    assert loaded["traceEvents"] == obj["traceEvents"]
    assert loaded["otherData"]["trace_meta"] == {"source": "test"}

    complete = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert complete and all(e["dur"] >= 0 for e in complete)
    assert all(e["ts"] >= 0 for e in complete)
    rounds_tracks = {e["tid"] for e in complete}
    assert obs.export.MAIN_TID in rounds_tracks
    # metadata names every referenced track
    named = {e["tid"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert rounds_tracks <= named
    # per-round counters rendered as counter events
    assert any(e["ph"] == "C" and e["name"] == "net_cost"
               for e in obj["traceEvents"])


def test_export_anchors_pre_v4_faults_to_round_span(tmp_path):
    records = [
        {"ev": "span", "v": 4, "round": 0, "name": "round", "span_id": 1,
         "parent_id": None, "t0_s": 0.0, "dur_s": 2.0, "attrs": {}},
        {"ev": "fault", "v": 3, "round": 0, "kind": "dropout",
         "injected": True, "device": 2, "detail": {}},
        {"ev": "fault", "v": 3, "round": 5, "kind": "dropout",
         "injected": True, "device": 2, "detail": {}},  # no round span
    ]
    obj = obs.to_chrome_trace(records)
    instants = [e for e in obj["traceEvents"] if e["ph"] == "i"]
    assert len(instants) == 1  # the unanchorable one is skipped
    assert instants[0]["ts"] == pytest.approx(2.0 * 1e6)


def _faulty_trainer(telemetry, fail_power: bool):
    """Tiny trainer on the CCP evaluator; fail_power=True forces the
    power solver down the ccp->closed_form fallback every round."""
    train = SyntheticImages.make(200, side=8, seed=0)
    test = SyntheticImages.make(50, side=8, seed=1)
    data = non_iid_split(train, test, K=4, per_device=20,
                         mislabel_prop=0.2, seed=0)
    sys_ = default_system(K=4, N=3, Q=2, D_hat=8)
    cfg = FEELConfig(scheme="proposed", d_hat=8, gp_steps=20,
                     eval_every=1, power_evaluator="ccp")
    cc = cnn.CNNConfig(side=8)
    params = cnn.init(jax.random.PRNGKey(0), cc)
    model = types.SimpleNamespace(features=cnn.features, apply=cnn.apply,
                                  loss_fn=cnn.loss_fn,
                                  accuracy=cnn.accuracy)
    spec = FaultSpec(seed=0, power_fail_prob=1.0 if fail_power else 0.0)
    return FEELTrainer(sys_, data, model, params, cfg,
                       telemetry=telemetry, faults=spec)


def test_diff_names_power_fallback_as_top_contributor(tmp_path):
    base_path = str(tmp_path / "base.jsonl")
    tele = obs.Telemetry(path=base_path)
    _faulty_trainer(tele, fail_power=False).run(2)
    tele.close()

    head_path = str(tmp_path / "head.jsonl")
    tele = obs.Telemetry(path=head_path)
    _faulty_trainer(tele, fail_power=True).run(2)
    tele.close()

    d = obs.diff_traces(obs.load_trace(base_path),
                        obs.load_trace(head_path))
    assert d.faults, "forced power fallback produced no fault delta"
    top_key = d.faults[0][0]
    assert "power" in top_key  # the power solver is named, not a parent
    headline = d.headline()
    assert "power" in headline and "fault" in headline
    rendered = d.render()
    assert "fallback[power->closed_form]" in rendered
    assert "headline:" in rendered


def test_diff_of_identical_traces_is_quiet(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tele = obs.Telemetry(path=path)
    _tiny_trainer(telemetry=tele).run(1)
    tele.close()
    trace = obs.load_trace(path)
    d = obs.diff_traces(trace, trace)
    assert d.faults == [] and d.counters == []
    assert d.wall_by_path == [] and d.energy_by_device == []
    assert "equivalent" in d.headline()


def test_dash_renders_self_contained_html(tmp_path):
    path = str(tmp_path / "t.jsonl")
    reg = obs.Registry()
    obs.metrics.set_default(reg)
    tele = obs.Telemetry(path=path, meta={"source": "test-dash"})
    trainer = _tiny_trainer(telemetry=tele)
    trainer.monitor = obs.ConvergenceMonitor(trainer.sys, telemetry=tele,
                                             registry=reg)
    trainer.run(2)
    obs.metrics.set_default(None)
    tele.close()

    out = str(tmp_path / "report.html")
    obs.write_dashboard(path, out)
    with open(out, encoding="utf-8") as f:
        page = f.read()
    assert page.startswith("<!doctype html>")
    assert "test-dash" in page
    # self-contained: no external resource references of any kind
    for needle in ("http://", "https://", "<script src", "<link",
                   "@import", "url("):
        assert needle not in page, f"external reference: {needle}"
    assert "<svg" in page  # the charts are inline SVG
    assert "round timeline" in page.lower()
    assert "per-device energy" in page.lower()
    # the monitor's bound-gap gauge made it into the chart section
    assert "Convergence-bound gap" in page


def test_cli_subcommands_run(tmp_path, capsys):
    from repro.obs import __main__ as cli

    path = str(tmp_path / "t.jsonl")
    tele = obs.Telemetry(path=path)
    _tiny_trainer(telemetry=tele).run(1)
    tele.close()

    cli.main(["summary", path])
    assert "telemetry.round" in capsys.readouterr().out
    cli.main([path])  # historic no-subcommand form
    assert "telemetry.round" in capsys.readouterr().out
    out_json = str(tmp_path / "t.json")
    cli.main(["export", path, "-o", out_json])
    assert "spans" in capsys.readouterr().out
    with open(out_json) as f:
        json.load(f)
    cli.main(["diff", path, path])
    assert "headline" in capsys.readouterr().out
    out_html = str(tmp_path / "r.html")
    cli.main(["dash", path, "-o", out_html])
    capsys.readouterr()
    assert open(out_html).read().startswith("<!doctype html>")


# ----------------------------------------------------------- robustness

def test_write_failure_drops_instead_of_crashing(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tele = obs.Telemetry(path=path)
    tele._file.close()  # simulate the file dying under the sink
    with pytest.warns(UserWarning, match="trace write failed"):
        tele.solver("power", method="closed_form", feasible=True)
    assert tele.dropped_writes == 1
    # sink keeps recording in memory, later writes don't warn again
    tele.solver("power", method="closed_form", feasible=True)
    assert tele.dropped_writes == 1  # file detached after first failure
    assert len(tele.events) == 2
    tele.close()


def test_out_of_order_span_exit_is_tolerated():
    tele = obs.Telemetry()
    a = tele.span("a").__enter__()
    b = tele.span("b").__enter__()
    # a exits first (crash-path ordering); b's id is popped from the
    # stack, and a still records its own id without raising
    a.__exit__(None, None, None)
    assert tele._span_stack == []
    b.__exit__(None, None, None)
    names = [e.name for e in tele.events]
    assert names == ["a", "b"]
