"""Per-architecture smoke tests: REDUCED same-family configs run one
forward/train step + prefill + decode on CPU; shapes and finiteness
asserted. (Full configs are exercised only by the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS, get_config, smoke_config
from repro.models import (init_model, make_cache, make_decode_step,
                          make_prefill_step, make_train_step, param_count)

B, S = 2, 16


def batch_for(cfg, B=B, S=S, labels=True):
    key = jax.random.PRNGKey(1)
    if cfg.modality == "text":
        t = jax.random.randint(key, (B, S), 0, cfg.vocab)
        b = {"tokens": t}
        if labels:
            b["labels"] = t
    elif cfg.modality == "vlm":
        b = {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                         cfg.act_dtype),
             "positions": jnp.broadcast_to(
                 jnp.arange(S)[None, None, :], (B, 3, S)).astype(jnp.int32)}
        if labels:
            b["labels"] = jax.random.randint(jax.random.fold_in(key, 1),
                                             (B, S), 0, cfg.vocab)
    else:
        t = jax.random.randint(key, (B, cfg.n_codebooks, S), 0, cfg.vocab)
        b = {"tokens": t}
        if labels:
            b["labels"] = t
    return b


def decode_batch(cfg, B=B, index=S):
    if cfg.modality == "text":
        return {"tokens": jnp.zeros((B, 1), jnp.int32),
                "cache_index": jnp.int32(index)}
    if cfg.modality == "vlm":
        return {"embeds": jnp.zeros((B, 1, cfg.d_model), cfg.act_dtype),
                "positions": jnp.full((B, 3, 1), index, jnp.int32),
                "cache_index": jnp.int32(index)}
    return {"tokens": jnp.zeros((B, cfg.n_codebooks, 1), jnp.int32),
            "cache_index": jnp.int32(index)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduced_variant(arch):
    cfg = smoke_config(arch)
    assert cfg.n_layers <= 6 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = batch_for(cfg)
    params2, state2, metrics = step(params, state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0

    # prefill + decode produce sane shapes
    logits, cache = jax.jit(make_prefill_step(cfg))(params, batch_for(
        cfg, labels=False))
    if cfg.modality == "audio":
        assert logits.shape == (B, 1, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, 1, cfg.vocab)
    full = make_cache(cfg, B, S + 4)

    def graft(dst, src):
        if dst.shape == src.shape:
            return src
        pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src, pad).astype(dst.dtype)

    cache = jax.tree.map(graft, full, cache)
    dl, _ = jax.jit(make_decode_step(cfg))(params, cache, decode_batch(cfg))
    assert np.all(np.isfinite(np.asarray(dl, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_card_dims(arch):
    """The full configs carry the exact assignment-card dimensions."""
    cfg = get_config(arch)
    card = {
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "deepseek-v3-671b": (61, 7168, 128, 128, None, 129280),
        "deepseek-v2-236b": (60, 5120, 128, 128, None, 102400),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "llama3_2-3b": (28, 3072, 24, 8, 8192, 128256),
        "falcon-mamba-7b": (64, 4096, None, None, None, 65024),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    }[arch]
    L, d, H, Hk, ff, V = card
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == V
    if H is not None:
        assert cfg.n_heads == H and cfg.n_kv_heads == Hk
    if ff is not None:
        assert cfg.d_ff == ff
    if arch.startswith("deepseek"):
        assert cfg.kv_lora == 512
        assert (cfg.n_experts, cfg.topk) == \
            ((256, 8) if "v3" in arch else (160, 6))
        assert cfg.moe_d_ff == (2048 if "v3" in arch else 1536)
    if arch == "falcon-mamba-7b":
        assert cfg.ssm_state == 16 and cfg.layer_pattern == ("mamba",)
    if arch == "recurrentgemma-9b":
        assert cfg.layer_pattern == ("rglru", "rglru", "attn_local")
    if arch == "gemma3-12b":
        assert cfg.layer_pattern.count("attn_local") == 5
    if arch == "qwen2-vl-2b":
        assert cfg.mrope_sections == (16, 24, 24)
    if arch == "musicgen-medium":
        assert cfg.n_codebooks == 4


def test_param_counts_match_cards():
    """Full-size param counts are in the advertised ballpark."""
    import repro.launch.shapes  # noqa: F401  (for eval_shape path)
    expect = {"llama3.2-3b": (2.8e9, 4.0e9),
              "falcon-mamba-7b": (6.5e9, 8.5e9),
              "gemma3-12b": (10e9, 14e9),
              "command-r-35b": (32e9, 40e9),
              "deepseek-v2-236b": (200e9, 260e9),
              "deepseek-v3-671b": (620e9, 720e9)}
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        from repro.models import init_model
        abstract = jax.eval_shape(lambda k, c=cfg: init_model(k, c),
                                  jax.random.PRNGKey(0))
        n = sum(np.prod(x.shape) for x in jax.tree.leaves(abstract))
        assert lo < n < hi, (arch, f"{n:.3g}")
