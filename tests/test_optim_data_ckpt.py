"""Optimizers, schedules, data pipeline, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional test extra; property tests skip
    from _hypothesis_stub import given, settings, st

from repro import optim
from repro.checkpoint import load_pytree, save_pytree
from repro.data import SyntheticImages, mislabel, non_iid_split


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw",
                                  "adafactor"])
def test_optimizers_minimize_quadratic(name):
    builder = {"sgd": lambda: optim.sgd(0.1),
               "momentum": lambda: optim.momentum(0.05),
               "adam": lambda: optim.adam(0.1),
               "adamw": lambda: optim.adamw(0.1, weight_decay=0.0),
               "adafactor": lambda: optim.adafactor(0.3)}[name]
    opt = builder()
    target = jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)

    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    assert float(loss(params)) < 0.05 * float(jnp.sum(target ** 2))


def test_adafactor_state_is_factored():
    opt = optim.adafactor(1e-2)
    params = {"w": jnp.zeros((64, 32)), "v": jnp.zeros((16,))}
    state = opt.init(params)
    assert state.vr["w"].shape == (64,)
    assert state.vc["w"].shape == (32,)
    assert state.vr["v"].shape == (16,)


def test_clip_by_global_norm():
    opt = optim.chain(optim.clip_by_global_norm(1.0), optim.sgd(1.0))
    state = opt.init({"w": jnp.zeros(3)})
    upd, _ = opt.update({"w": jnp.asarray([3.0, 4.0, 0.0])}, state, None)
    norm = float(jnp.linalg.norm(upd["w"]))
    assert abs(norm - 1.0) < 1e-5


def test_schedules():
    from repro.optim import cosine_schedule, warmup_cosine
    cos = cosine_schedule(100, final_frac=0.1)
    assert float(cos(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.1)
    wc = warmup_cosine(10, 110)
    assert float(wc(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.0, 0.5), st.integers(10, 200))
def test_mislabel_proportion(prop, n):
    labels = np.random.default_rng(0).integers(0, 10, n).astype(np.int32)
    bad, mask = mislabel(labels, prop, 10, seed=1)
    assert mask.sum() == int(round(prop * n))
    # every flagged label is actually wrong, every unflagged is intact
    assert np.all(bad[mask] != labels[mask])
    assert np.all(bad[~mask] == labels[~mask])


def test_non_iid_split_single_label():
    data = SyntheticImages.make(500, side=12, seed=0)
    test = SyntheticImages.make(100, side=12, seed=1)
    fd = non_iid_split(data, test, K=5, per_device=30, mislabel_prop=0.2,
                       seed=0)
    for k in range(5):
        assert np.all(fd.device_true[k] == k % 10)
        frac_bad = np.mean(fd.device_labels[k] != fd.device_true[k])
        assert abs(frac_bad - 0.2) < 0.05


def test_synthetic_classes_are_separable():
    """A linear probe must beat chance comfortably — otherwise the
    paper-validation experiments would be meaningless."""
    data = SyntheticImages.make(1200, side=12, seed=0)
    X = data.images.reshape(len(data), -1)
    y = data.true_labels
    Xtr, ytr, Xte, yte = X[:1000], y[:1000], X[1000:], y[1000:]
    # one-step ridge classifier
    A = np.concatenate([Xtr, np.ones((len(Xtr), 1))], axis=1)
    Y = np.eye(10)[ytr]
    W = np.linalg.solve(A.T @ A + 1e-1 * np.eye(A.shape[1]), A.T @ Y)
    pred = np.argmax(
        np.concatenate([Xte, np.ones((len(Xte), 1))], axis=1) @ W, axis=1)
    acc = float(np.mean(pred == yte))
    assert acc > 0.5, acc


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "list": [jnp.zeros((2,)), jnp.ones((2,))]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_pytree(path, tree, metadata={"step": 7})
        out = load_pytree(path, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        from repro.checkpoint.checkpoint import load_metadata
        assert load_metadata(path)["step"] == 7


def test_mnist_loader_fallback_and_idx():
    """Loader falls back to synthetic offline and parses IDX when
    files exist."""
    import gzip
    import struct
    import tempfile

    from repro.data.mnist import available, load_mnist

    with tempfile.TemporaryDirectory() as d:
        assert not available(d)
        tr, te = load_mnist(d, fallback_n=(50, 20), fallback_side=12)
        assert tr.images.shape == (50, 12, 12)
        assert te.images.shape == (20, 12, 12)

        # write tiny real IDX files (gz) and check exact parse
        rng = np.random.default_rng(0)
        imgs = rng.integers(0, 255, (6, 28, 28)).astype(np.uint8)
        labs = rng.integers(0, 10, (6,)).astype(np.uint8)

        def write_idx(path, arr):
            with gzip.open(path + ".gz", "wb") as f:
                f.write(struct.pack(f">I{arr.ndim}I",
                                    0x800 + arr.ndim, *arr.shape))
                f.write(arr.tobytes())

        for name, arr in (("train-images-idx3-ubyte", imgs),
                          ("train-labels-idx1-ubyte", labs),
                          ("t10k-images-idx3-ubyte", imgs),
                          ("t10k-labels-idx1-ubyte", labs)):
            write_idx(os.path.join(d, name), arr)
        assert available(d)
        tr, te = load_mnist(d)
        assert tr.images.shape == (6, 28, 28)
        np.testing.assert_allclose(tr.images * 255.0, imgs, atol=0.5)
        np.testing.assert_array_equal(tr.true_labels, labs)
