"""Import-safe fallback when ``hypothesis`` (an optional test extra,
see pyproject.toml) is not installed.

A module-level ``pytest.importorskip("hypothesis")`` would skip the
*entire* test module, losing its plain unit tests too.  Instead the
test modules do::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:            # property tests skip, unit tests run
        from _hypothesis_stub import given, settings, st

and only the ``@given``-decorated property tests are skipped.
"""
import pytest


def given(*_args, **_kwargs):
    """Replace the property test with a skip marker."""

    def deco(fn):
        return pytest.mark.skip(
            reason="hypothesis not installed (pip install 'repro-feel[test]')"
        )(fn)

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


class _AnyStrategy:
    """Stands in for ``hypothesis.strategies``: any attribute is a
    callable returning None (strategies are only inspected by ``given``,
    which the stub ignores)."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _AnyStrategy()
