"""Executable fallback when ``hypothesis`` (a test extra, see
pyproject.toml) is not installed.

The test modules do::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, st

Under real hypothesis the property tests get its full engine
(shrinking, the example database, health checks).  Under this stub
they still *run*: ``given`` draws a deterministic, seeded, bounded
batch of examples per test (no shrinking — the failure report simply
prints the falsifying example).  The subset implemented is exactly
what tests/ and tests/strategies.py use: ``integers``, ``floats``,
``booleans``, ``sampled_from``, ``just``, ``one_of``, ``lists``,
``tuples``, ``composite``, ``.map``/``.filter``, ``assume``,
``settings(max_examples=, deadline=)``.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

#: examples per property when ``settings`` doesn't say otherwise —
#: bounded so a stub run stays CPU-container friendly.
DEFAULT_MAX_EXAMPLES = 20
#: give up on a property whose ``assume``/``filter`` rejects this many
#: consecutive candidates (mirrors hypothesis' filter_too_much).
MAX_REJECTS = 200


class UnsatisfiedAssumption(Exception):
    """Raised by ``assume(False)`` — the example is discarded."""


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class Strategy:
    """A seeded sampler: ``_sample(rng) -> value``."""

    def __init__(self, sample):
        self._sample = sample

    def example_from(self, rng: np.random.Generator):
        return self._sample(rng)

    def map(self, f) -> "Strategy":
        return Strategy(lambda rng: f(self._sample(rng)))

    def filter(self, pred) -> "Strategy":
        def sample(rng):
            for _ in range(MAX_REJECTS):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise UnsatisfiedAssumption()

        return Strategy(sample)


class _DrawFn:
    """The ``draw`` callable handed to ``@composite`` functions."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def __call__(self, strategy: Strategy):
        return strategy.example_from(self._rng)


class _Strategies:
    """The ``strategies as st`` namespace."""

    @staticmethod
    def integers(min_value=0, max_value=None) -> Strategy:
        if max_value is None:
            min_value, max_value = 0, min_value
        return Strategy(lambda rng: int(rng.integers(min_value,
                                                     max_value + 1)))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw) -> Strategy:
        # bounded uniform; nan/inf never produced (matches the
        # bounded-floats behaviour of real hypothesis)
        return Strategy(lambda rng: float(min_value + (max_value - min_value)
                                          * rng.random()))

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(elements) -> Strategy:
        elements = list(elements)
        return Strategy(lambda rng: elements[rng.integers(len(elements))])

    @staticmethod
    def just(value) -> Strategy:
        return Strategy(lambda rng: value)

    @staticmethod
    def one_of(*strategies) -> Strategy:
        return Strategy(lambda rng: strategies[rng.integers(
            len(strategies))].example_from(rng))

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: int = 10, **_kw) -> Strategy:
        def sample(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example_from(rng) for _ in range(n)]

        return Strategy(sample)

    @staticmethod
    def tuples(*strategies) -> Strategy:
        return Strategy(lambda rng: tuple(s.example_from(rng)
                                          for s in strategies))

    @staticmethod
    def composite(fn):
        @functools.wraps(fn)
        def builder(*args, **kwargs):
            return Strategy(lambda rng: fn(_DrawFn(rng), *args, **kwargs))

        return builder


st = _Strategies()


def given(*gargs, **gkwargs):
    """Run the property over a deterministic, seeded example batch.

    The seed derives from the test's qualified name, so a failure
    reproduces run to run; the falsifying example is printed in the
    raised assertion's chain.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_ex = getattr(wrapper, "_stub_max_examples",
                             DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            examples = rejects = 0
            while examples < max_ex:
                try:
                    vals = [s.example_from(rng) for s in gargs]
                    kvals = {k: s.example_from(rng)
                             for k, s in gkwargs.items()}
                except UnsatisfiedAssumption:
                    rejects += 1
                    if rejects > MAX_REJECTS:
                        raise RuntimeError(
                            f"{fn.__qualname__}: strategies rejected "
                            f"{MAX_REJECTS} candidates in a row")
                    continue
                try:
                    fn(*args, *vals, **kwargs, **kvals)
                except UnsatisfiedAssumption:
                    rejects += 1
                    if rejects > MAX_REJECTS:
                        raise RuntimeError(
                            f"{fn.__qualname__}: assume() rejected "
                            f"{MAX_REJECTS} candidates in a row")
                    continue
                except Exception as e:
                    shown = vals + (sorted(kvals.items()) if kvals else [])
                    raise AssertionError(
                        f"falsifying example ({fn.__qualname__}, "
                        f"example #{examples}): {shown!r}") from e
                examples += 1
                rejects = 0

        # pytest must not see the property's drawn parameters as
        # fixtures: hide the original signature (hypothesis does the
        # same for parameters its strategies supply).
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper._stub_given = True
        return wrapper

    return deco


def settings(max_examples=None, deadline=None, **_kw):
    """Record the example budget on the (given-wrapped) test."""
    del deadline  # the stub has no deadline watchdog

    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = int(max_examples)
        return fn

    return deco
