"""Shared hypothesis strategies for FEEL property tests.

Works under real ``hypothesis`` (CI installs the ``[test]`` extra) and
under ``tests/_hypothesis_stub.py`` (the seeded bounded fallback) —
both expose the same ``composite``/``integers``/``floats`` subset.

Array-valued data (channel matrices, sigma scores, mislabel masks) is
derived from a drawn integer seed through ``np.random.default_rng``
rather than element-wise float strategies: examples stay small and
reproducible, and under real hypothesis shrinking works on the seed
and the shape parameters, which is what matters for these solvers.
"""
from __future__ import annotations

import numpy as np

try:
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised in stub-only envs
    from _hypothesis_stub import st

from repro.core import default_system


@st.composite
def system_params(draw, max_k: int = 6, max_n: int = 4, max_q: int = 3,
                  min_k: int = 2):
    """A small random ``SystemParams`` (paper Table-I shape).

    Capacity N*Q is NOT forced to cover K — partial matchings are part
    of the contract under test.
    """
    K = draw(st.integers(min_k, max_k))
    N = draw(st.integers(1, max_n))
    Q = draw(st.integers(1, max_q))
    D_hat = draw(st.integers(8, 64))
    lam = draw(st.floats(1e-4, 1e-2))
    return default_system(K=K, N=N, Q=Q, D_hat=D_hat, lam=lam)


@st.composite
def channel_matrix(draw, K: int, N: int, mean_gain: float = 1e-5):
    """(K, N) i.i.d. gamma channel gains from a drawn seed."""
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return rng.gamma(2.0, mean_gain / 2.0, size=(K, N))


@st.composite
def availability(draw, K: int, p_avail: float = 0.8):
    """(K,) 0/1 availability draw with at least one available device."""
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    alpha = (rng.random(K) < p_avail).astype(np.float64)
    if alpha.sum() == 0:
        alpha[rng.integers(K)] = 1.0
    return alpha


@st.composite
def matching_instance(draw, max_k: int = 6, max_n: int = 4,
                      max_q: int = 3, min_k: int = 2):
    """(sys, h, alpha) ready for ``swap_matching``."""
    sys_ = draw(system_params(max_k=max_k, max_n=max_n, max_q=max_q,
                              min_k=min_k))
    h = draw(channel_matrix(sys_.K, sys_.N))
    alpha = draw(availability(sys_.K))
    return sys_, h, alpha


@st.composite
def sigma_scores(draw, K: int, J: int):
    """(K, J) nonnegative per-sample gradient-norm scores."""
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return rng.gamma(2.0, 1.0, size=(K, J)).astype(np.float32)


@st.composite
def mislabel_mask(draw, K: int, J: int):
    """(K, J) boolean mislabel indicator with drawn corruption rate."""
    seed = draw(st.integers(0, 2**31 - 1))
    prop = draw(st.floats(0.0, 0.5))
    rng = np.random.default_rng(seed)
    return rng.random((K, J)) < prop


@st.composite
def selection_instance(draw, max_k: int = 6, max_j: int = 24):
    """(sys, sigma, mask) ready for the data-selection solvers."""
    sys_ = draw(system_params(max_k=max_k))
    J = draw(st.integers(2, max_j))
    sigma = draw(sigma_scores(sys_.K, J))
    mask = np.ones((sys_.K, J), np.float32)
    return sys_, sigma, mask
