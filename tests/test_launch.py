"""Launch-layer tests: sharding specs, collective parser, host-mesh
lowering of a smoke config (the 512-device production meshes are
exercised by the dry-run sweep, recorded in EXPERIMENTS.md)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.launch import make_host_mesh
from repro.launch.dryrun import collective_bytes
from repro.launch.sharding import _param_spec


def test_param_spec_megatron_pairing():
    kw = dict(model=16, data=16, data_ax=("data",), skip_leading=False,
              is_expert=False)
    # column-parallel: out features over model
    assert _param_spec("wq", (4096, 4096), **kw) == P(None, "model")
    assert _param_spec("w_gate", (4096, 16384), **kw) == P(None, "model")
    # row-parallel: contraction over model, ZeRO data on the out dim
    assert _param_spec("wo", (4096, 4096), **kw) == P("model", ("data",))
    assert _param_spec("w_down", (16384, 4096), **kw) \
        == P("model", ("data",))
    # embed: vocab-parallel + data on features
    assert _param_spec("embed", (128000, 4096), **kw) \
        == P("model", ("data",))
    # norms replicate
    assert _param_spec("ln1", (4096,), **kw) == P(None)
    # non-divisible dims stay unsharded
    assert _param_spec("wk", (4096, 24), **kw) == P(None, None)


def test_param_spec_scan_stacked_and_experts():
    kw = dict(model=16, data=16, data_ax=("data",), skip_leading=True,
              is_expert=False)
    assert _param_spec("wq", (28, 4096, 4096), **kw) \
        == P(None, None, "model")
    kw["is_expert"] = True
    # E divisible by data*model -> joint expert sharding (1 expert/chip;
    # EXPERIMENTS.md §Perf pair B iter 2)
    assert _param_spec("w_gate", (28, 256, 7168, 2048), **kw) \
        == P(None, ("data", "model"), None, None)
    # E=160: fallback expert-parallel + ZeRO on the per-expert features
    assert _param_spec("w_gate", (28, 160, 5120, 1536), **kw) \
        == P(None, "model", None, ("data",))


def test_collective_parser():
    hlo = """
  %all-reduce.1 = f32[128,1024]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[64,512]{1,0} all-gather(%y), dimensions={0}
  %tup = (f32[10,10]{1,0}, f32[10,10]{1,0}) all-to-all(%a, %b)
  %not_a_collective = f32[5,5]{1,0} add(%p, %q)
  %rs.7 = bf16[32]{0} reduce-scatter(%z), dimensions={0}
  %cp = u32[16]{0} collective-permute-start(%w)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 1024 * 4
    assert got["all-gather"] == 64 * 512 * 2
    assert got["all-to-all"] == 2 * 10 * 10 * 4
    assert got["reduce-scatter"] == 32 * 2
    assert got["collective-permute"] == 16 * 4
    assert got["count"] == 5


@pytest.mark.slow
def test_host_mesh_lowering_smoke():
    """A reduced config lowers+compiles under a real (1x1) mesh with the
    production sharding rules — the same code path the 512-dev dry-run
    uses."""
    from repro.launch import sharding as sh
    from repro.models import init_model, make_train_step
    from repro.launch.shapes import make_optimizer
    cfg = smoke_config("llama3_2-3b")
    mesh = make_host_mesh(1, 1)
    params_abs = jax.eval_shape(lambda k: init_model(k, cfg),
                                jax.random.PRNGKey(0))
    p_sh = sh.param_shardings(mesh, params_abs, cfg)
    # every leaf got a NamedSharding with a valid spec
    for leaf in jax.tree.leaves(p_sh):
        assert leaf.mesh is mesh

    opt = make_optimizer(cfg)
    step = make_train_step(cfg, opt)
    toks = jax.ShapeDtypeStruct((4, 16), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    opt_abs = jax.eval_shape(opt.init, params_abs)
    with mesh, sh.with_mesh_constraints(mesh):
        lowered = jax.jit(step).lower(params_abs, opt_abs, batch)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # jax < 0.4.34 returns one dict per device
        cost = cost[0]
    assert cost["flops"] > 0


def test_shapes_applicability_gates():
    from repro.launch.shapes import LONG_OK, applicable
    assert applicable("falcon-mamba-7b", "long_500k")
    assert applicable("gemma3-12b", "long_500k")
    assert not applicable("command-r-35b", "long_500k")
    assert not applicable("deepseek-v3-671b", "long_500k")
    assert all(applicable(a, "train_4k") for a in LONG_OK)
