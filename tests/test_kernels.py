"""Per-kernel allclose validation against the pure-jnp oracles
(interpret mode), with shape/dtype sweeps + hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional test extra; property tests skip
    from _hypothesis_stub import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gradnorm import rownorm2
from repro.kernels.lru_scan import lru_scan


@pytest.mark.parametrize("bh,s,d", [(4, 128, 64), (2, 200, 32),
                                    (3, 513, 128), (1, 64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(bh, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q, k, v = (jax.random.normal(kk, (bh, s, d), dtype) for kk in ks)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (2, 96, 64), jnp.float32) for kk in ks)
    got = flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bhsd_wrapper():
    B, S, H, d = 2, 130, 3, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, d), jnp.float32)
               for kk in ks)
    got = ops.flash_attention_bhsd(q, k, v)
    fold = lambda x: jnp.moveaxis(x, 2, 1).reshape(B * H, S, d)
    want = ref.flash_attention_ref(fold(q), fold(k), fold(v))
    want = jnp.moveaxis(want.reshape(B, H, S, d), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("n,f", [(10, 50), (300, 700), (8, 4096),
                                 (1000, 130)])
def test_rownorm2_matches_ref(n, f):
    x = jax.random.normal(jax.random.PRNGKey(n * f), (n, f))
    got = rownorm2(x, interpret=True)
    want = ref.rownorm2_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5)


def test_gradnorm_sigma_equals_autodiff():
    """The fused score equals the true per-sample last-layer grad norm."""
    key = jax.random.PRNGKey(3)
    N, D, V = 12, 20, 7
    h = jax.random.normal(key, (N, D))
    W = jax.random.normal(jax.random.fold_in(key, 1), (D, V)) * 0.3
    b = jnp.zeros((V,))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (N,), 0, V)

    def loss_one(Wb, hi, yi):
        W, b = Wb
        logits = hi @ W + b
        return -jax.nn.log_softmax(logits)[yi]

    sig_true = []
    for i in range(N):
        g = jax.grad(loss_one)((W, b), h[i], labels[i])
        sig_true.append(float(sum(jnp.sum(x ** 2)
                                  for x in jax.tree.leaves(g))))
    logits = h @ W + b
    got = ops.sigma_from_head(h, logits, labels, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(sig_true),
                               rtol=1e-4)


@pytest.mark.parametrize("b,s,c", [(1, 17, 8), (2, 300, 130),
                                   (3, 256, 256), (2, 512, 64)])
def test_lru_scan_matches_sequential(b, s, c):
    key = jax.random.PRNGKey(b * s + c)
    a = jax.random.uniform(key, (b, s, c), minval=0.3, maxval=0.999)
    bb = jax.random.normal(jax.random.fold_in(key, 1), (b, s, c))
    got = lru_scan(a, bb, interpret=True)
    want = ref.lru_scan_ref(a, bb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 60), st.integers(1, 40))
def test_lru_scan_property(b, s, c):
    key = jax.random.PRNGKey(b * 1000 + s * 10 + c)
    a = jax.random.uniform(key, (b, s, c), minval=0.0, maxval=1.0)
    bb = jax.random.normal(jax.random.fold_in(key, 1), (b, s, c))
    got = np.asarray(lru_scan(a, bb, interpret=True))
    want = np.asarray(ref.lru_scan_ref(a, bb))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # property: with a == 0 the scan is the identity on b
    got0 = np.asarray(lru_scan(jnp.zeros_like(a), bb, interpret=True))
    np.testing.assert_allclose(got0, np.asarray(bb), rtol=1e-5, atol=1e-6)


def test_lru_scan_matches_associative_scan_path():
    """Kernel == the jnp associative_scan the models actually use."""
    from repro.models.ssm import _scan_assoc
    key = jax.random.PRNGKey(9)
    a = jax.random.uniform(key, (2, 64, 32), minval=0.5, maxval=0.99)
    b = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 32))
    got = np.asarray(lru_scan(a, b, interpret=True))
    want = np.asarray(_scan_assoc(a[..., None], b[..., None])[..., 0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
