"""Tests for data selection (Algorithms 4-5) and the exact oracle."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional test extra; property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import default_system, sample_round
from repro.core import delta as delta_mod
from repro.core import selection as sel_mod


def make(seed=0, K=4, D=6):
    sys_ = default_system(K=K, N=3, Q=2, D_hat=D)
    st_ = sample_round(jax.random.PRNGKey(seed), sys_)
    return sys_, st_


def brute_force_optimum(sys_, sigma, mask):
    """Enumerate all feasible binary selections (tiny instances only)."""
    K, J = sigma.shape
    sigma = np.asarray(sigma)
    best_val, best_sel = np.inf, None
    per_device = []
    for k in range(K):
        opts = []
        J_k = int(np.asarray(mask)[k].sum())
        for r in range(1, J_k + 1):
            for idx in itertools.combinations(range(J_k), r):
                opts.append(idx)
        per_device.append(opts)
    # per-device decoupling means we can optimize each device separately
    A = np.asarray(sys_.a_weights())
    q = np.asarray(sys_.q)
    lam = float(sys_.lam)
    sel = np.zeros((K, J), np.float32)
    for k in range(K):
        best_k, best_idx = np.inf, None
        for idx in per_device[k]:
            s = sigma[k, list(idx)]
            val = lam * A[k] * s.mean() - (1 - lam) * q[k] * len(idx)
            if val < best_k:
                best_k, best_idx = val, idx
        sel[k, list(best_idx)] = 1.0
    return sel


def objective(sys_, d, sigma):
    return float(delta_mod.selection_only_objective(sys_, d, sigma))


def test_exact_selection_matches_bruteforce():
    for seed in range(5):
        sys_, st_ = make(seed=seed)
        d_star = brute_force_optimum(sys_, st_.sigma, st_.sigma_mask)
        d_got = sel_mod.exact_selection(sys_, st_.sigma, st_.sigma_mask)
        v_star = objective(sys_, jnp.asarray(d_star), st_.sigma)
        v_got = objective(sys_, d_got, st_.sigma)
        assert np.isclose(v_got, v_star, rtol=1e-5), (seed, v_got, v_star)


def test_faithful_selection_feasible_and_near_oracle():
    sys_, st_ = make(seed=3, K=6, D=10)
    d = sel_mod.faithful_selection(sys_, st_.sigma, st_.sigma_mask,
                                   step0=5.0)
    d_np = np.asarray(d)
    mask = np.asarray(st_.sigma_mask)
    assert set(np.unique(d_np)).issubset({0.0, 1.0})
    assert np.all(d_np <= mask)
    assert np.all(d_np.sum(axis=1) >= 1)  # constraint (25)
    v_faith = objective(sys_, d, st_.sigma)
    v_exact = objective(sys_, sel_mod.exact_selection(
        sys_, st_.sigma, st_.sigma_mask), st_.sigma)
    # the paper's algorithm is suboptimal but should be in the ballpark
    assert v_faith >= v_exact - 1e-6  # oracle really is a lower bound
    assert v_faith <= v_exact + 0.35 * abs(v_exact) + 1.0


def test_binary_recovery_is_lp_optimum():
    """Threshold-at-1/2 equals brute-force minimization of (38)."""
    rng = np.random.default_rng(0)
    for _ in range(10):
        K, J = 3, 4
        d_cont = rng.uniform(0, 1, (K, J)).astype(np.float32)
        mask = np.ones((K, J), np.float32)
        got = np.asarray(sel_mod.binary_recovery(jnp.asarray(d_cont),
                                                 jnp.asarray(mask)))
        # brute force min ||delta - d_cont||^2 over feasible binaries
        best_val, best = np.inf, None
        for bits in itertools.product([0, 1], repeat=K * J):
            cand = np.array(bits, np.float32).reshape(K, J)
            if np.any(cand.sum(axis=1) < 1):
                continue
            val = float(np.sum((cand - d_cont) ** 2))
            if val < best_val - 1e-12:
                best_val, best = val, cand
        got_val = float(np.sum((got - d_cont) ** 2))
        assert np.isclose(got_val, best_val, rtol=1e-6), (got_val, best_val)


def test_projection_feasible_set():
    rng = np.random.default_rng(1)
    z = jnp.asarray(rng.normal(0, 2, (5, 7)).astype(np.float32))
    mask = np.ones((5, 7), np.float32)
    mask[2, 4:] = 0
    out = np.asarray(sel_mod.project_feasible(z, jnp.asarray(mask)))
    assert np.all(out >= -1e-6) and np.all(out <= 1 + 1e-6)
    assert np.all(out.sum(axis=1) >= 1 - 1e-4)
    assert np.all(out[2, 4:] == 0)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_projection_is_idempotent_and_closer(seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(0, 2, (3, 5)).astype(np.float32))
    mask = jnp.ones((3, 5), jnp.float32)
    p1 = sel_mod.project_feasible(z, mask)
    p2 = sel_mod.project_feasible(p1, mask)
    assert np.allclose(np.asarray(p1), np.asarray(p2), atol=1e-4)
    # projection theorem: feasible points are no closer to z than proj(z)
    for _ in range(5):
        w = np.clip(rng.uniform(0, 1, (3, 5)), 0, 1).astype(np.float32)
        w = w / np.maximum(w.sum(1, keepdims=True), 1e-9)  # sums to 1
        d_w = float(np.sum((w - np.asarray(z)) ** 2))
        d_p = float(np.sum((np.asarray(p1) - np.asarray(z)) ** 2))
        assert d_p <= d_w + 1e-4
