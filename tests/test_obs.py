"""Tests for the repro.obs telemetry subsystem: JSONL round-trip,
no-op default sink, and the instrumented FEELTrainer round."""
import json
import time
import types

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import default_system
from repro.data import SyntheticImages, non_iid_split
from repro.fed import FEELConfig, FEELTrainer
from repro.models import cnn


# ------------------------------------------------------------------ trace

def test_trace_roundtrip_jsonl(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    with obs.Telemetry(path=path, meta={"who": "test"}) as tele:
        tele.begin_round(0)
        with tele.stage("matching"):
            time.sleep(0.01)
        tele.solver("matching", swaps=3, sweeps=2, feasible=True)
        tele.devices(energy_cmp_j=[1.0, 2.0], energy_com_j=[0.5, 0.5],
                     cost=[7.5, 12.5], reward=[0.1, 0.2],
                     selected=[4, 5], uploaded=[1, 0],
                     mislabel_frac=[0.25, 0.0])
        tele.round_end(wall_s=0.02, net_cost=-1.5, delta_obj=3.0,
                       n_selected=9, n_uploaded=1, feasible=True)

    records = obs.load_trace(path)
    assert records[0]["ev"] == "header"
    assert records[0]["v"] == obs.SCHEMA_VERSION
    assert records[0]["meta"] == {"who": "test"}
    kinds = [r["ev"] for r in records[1:]]
    assert kinds == ["stage", "solver", "devices", "round"]

    # every line is plain JSON; parse_record gives typed events back
    parsed = [obs.parse_record(r) for r in records]
    assert parsed[0] is None  # header has no event class
    st, so, dv, ro = parsed[1:]
    assert isinstance(st, obs.StageEvent) and st.stage == "matching"
    assert st.round == 0 and st.dur_s >= 0.01
    assert isinstance(so, obs.SolverEvent)
    assert so.counters["swaps"] == 3
    assert isinstance(dv, obs.DeviceEvent) and dv.selected == [4, 5]
    assert isinstance(ro, obs.RoundEvent) and ro.net_cost == -1.5

    # in-memory events and the file carry identical records
    assert [e.to_record() for e in tele.events] == records[1:]


def test_summary_aggregates_and_csv_rows(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with obs.Telemetry(path=path) as tele:
        for i in range(3):
            tele.begin_round(i)
            with tele.stage("sigma"):
                pass
            tele.solver("power", method="ccp", iterations=4,
                        feasible=(i != 1))
            tele.round_end(wall_s=0.5, net_cost=0.0, delta_obj=0.0,
                           n_selected=1, n_uploaded=1, feasible=(i != 1))

    s = obs.summarize(obs.load_trace(path))
    assert s.n_rounds == 3
    assert s.infeasible_rounds == 1
    assert s.stages["sigma"].calls == 3
    assert s.solvers["power"]["calls"] == 3
    assert s.solvers["power"]["iterations"] == 12
    assert s.solvers["power"]["infeasible"] == 1
    assert s.total_wall_s == pytest.approx(1.5)

    rows = obs.rows(s)
    names = [r[0] for r in rows]
    assert "telemetry.stage.sigma" in names
    assert "telemetry.solver.power" in names
    assert "telemetry.round" in names
    for name, us, derived in rows:
        assert isinstance(us, float) and "," not in derived  # CSV-safe

    # summarize accepts live event objects and raw dicts identically
    s2 = obs.summarize(tele.events)
    assert obs.rows(s2) == rows


def test_schema_version_mismatch_raises():
    with pytest.raises(ValueError):
        obs.parse_record({"ev": "stage", "v": obs.SCHEMA_VERSION + 1,
                          "stage": "x", "t0_s": 0.0, "dur_s": 0.0})


def test_null_sink_records_nothing(tmp_path):
    null = obs.NullTelemetry()
    with null.stage("matching"):
        pass
    null.solver("power", iterations=3)
    null.round_end(wall_s=0.0, net_cost=0.0, delta_obj=0.0, n_selected=0,
                   n_uploaded=0, feasible=True)
    assert not hasattr(null, "events")
    assert null.enabled is False
    # block is the identity when disabled (no device sync forced)
    x = object()
    assert null.block(x) is x
    # the process default is a no-op unless explicitly installed
    assert obs.get_default().enabled is False
    assert obs.resolve(None) is obs.get_default()
    tele = obs.Telemetry()
    assert obs.resolve(tele) is tele


def test_set_default_install_and_reset():
    tele = obs.Telemetry()
    obs.set_default(tele)
    try:
        assert obs.resolve(None) is tele
    finally:
        obs.set_default(None)
    assert obs.get_default() is obs.NULL


def test_load_trace_tolerates_truncated_final_line(tmp_path):
    path = str(tmp_path / "crash.jsonl")
    with obs.Telemetry(path=path) as tele:
        tele.begin_round(0)
        tele.solver("power", method="closed_form", feasible=True)
    # simulate a process dying mid-write
    with open(path, "a") as f:
        f.write('{"ev": "round", "v": 2, "wall_s": 0.')

    with pytest.warns(UserWarning, match="truncated final trace line"):
        records = obs.load_trace(path)
    assert [r["ev"] for r in records] == ["header", "solver"]

    # strict mode restores the raise
    with pytest.raises(json.JSONDecodeError):
        obs.load_trace(path, strict=True)

    # corruption anywhere else still raises in default mode
    bad = str(tmp_path / "corrupt.jsonl")
    with open(path) as f:
        lines = f.readlines()
    with open(bad, "w") as f:
        f.write(lines[0])
        f.write('{"ev": "solv\n')  # malformed *interior* line
        f.write(lines[1])
    with pytest.raises(json.JSONDecodeError):
        obs.load_trace(bad)


def test_telemetry_close_is_idempotent(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tele = obs.Telemetry(path=path)
    tele.begin_round(0)
    tele.solver("power", feasible=True)
    tele.close()
    tele.close()  # double close: no error, no re-registration issues
    assert obs.load_trace(path)[-1]["ev"] == "solver"
    # events stay readable in memory after close; file writes stop
    tele.solver("power", feasible=True)
    assert len(tele.events) == 2
    assert len(obs.load_trace(path)) == 2  # header + first solver only

    # context-manager exit and explicit close compose
    with obs.Telemetry(path=str(tmp_path / "u.jsonl")) as t2:
        t2.close()


# ------------------------------------------------------- trainer round

def _tiny_trainer(telemetry=None, scheme="proposed"):
    train = SyntheticImages.make(200, side=8, seed=0)
    test = SyntheticImages.make(50, side=8, seed=1)
    data = non_iid_split(train, test, K=4, per_device=20,
                         mislabel_prop=0.2, seed=0)
    sys_ = default_system(K=4, N=3, Q=2, D_hat=8)
    cfg = FEELConfig(scheme=scheme, d_hat=8, gp_steps=20, eval_every=1)
    cc = cnn.CNNConfig(side=8)
    params = cnn.init(jax.random.PRNGKey(0), cc)
    model = types.SimpleNamespace(features=cnn.features, apply=cnn.apply,
                                  loss_fn=cnn.loss_fn,
                                  accuracy=cnn.accuracy)
    return FEELTrainer(sys_, data, model, params, cfg, telemetry=telemetry)


def test_run_round_emits_six_stages_with_consistent_timings(tmp_path):
    path = str(tmp_path / "round.jsonl")
    tele = obs.Telemetry(path=path)
    trainer = _tiny_trainer(telemetry=tele)
    m = trainer.run_round(0, eval_now=False)
    tele.close()

    stage_evs = [e for e in tele.events if isinstance(e, obs.StageEvent)]
    round_evs = [e for e in tele.events if isinstance(e, obs.RoundEvent)]
    assert len(round_evs) == 1
    wall = round_evs[0].wall_s

    names = [e.stage for e in stage_evs]
    for required in obs.REQUIRED_STAGES:
        assert required in names, f"missing stage {required}"

    # timings are monotonically consistent: stages are emitted in
    # increasing start order, each has non-negative duration, no stage
    # overruns the round, and together they account for the round wall
    starts = [e.t0_s for e in stage_evs]
    assert starts == sorted(starts)
    assert all(e.dur_s >= 0.0 for e in stage_evs)
    assert all(e.round == 0 for e in stage_evs)
    total = sum(e.dur_s for e in stage_evs)
    assert total <= wall * 1.01 + 1e-6
    assert total >= 0.5 * wall  # stages explain the bulk of the round

    # the trace on disk round-trips to the same picture
    s = obs.summarize(obs.load_trace(path))
    assert s.n_rounds == 1
    assert set(obs.REQUIRED_STAGES) <= set(s.stages)

    # device event matches the round metrics
    dev = [e for e in tele.events if isinstance(e, obs.DeviceEvent)][0]
    assert sum(dev.selected) == m.n_selected
    assert sum(dev.uploaded) == m.n_uploaded
    assert len(dev.energy_cmp_j) == 4
    assert all(v >= 0 for v in dev.energy_com_j)
    # net cost (eq. 18) == sum_k cost_k - sum_k reward_k
    assert (sum(dev.cost) - sum(dev.reward)
            == pytest.approx(m.net_cost, rel=1e-4, abs=1e-7))


def test_trainer_disabled_by_default_and_unchanged():
    trainer = _tiny_trainer()
    assert trainer.obs.enabled is False
    m = trainer.run_round(0)
    assert np.isfinite(m.net_cost)

    # telemetry does not perturb training numerics
    t2 = _tiny_trainer(telemetry=obs.Telemetry())
    m2 = t2.run_round(0)
    assert m2.net_cost == pytest.approx(m.net_cost)
    assert m2.n_selected == m.n_selected
    assert m2.n_uploaded == m.n_uploaded


def test_full_observability_is_bit_for_bit_identical(tmp_path):
    """The whole observability stack — trace + profiling + metrics +
    monitor — must not change a single bit of the training state."""
    plain = _tiny_trainer()
    ms_plain = plain.run(2)

    reg = obs.Registry()
    obs.metrics.set_default(reg)
    tele = obs.Telemetry(path=str(tmp_path / "t.jsonl"), profile=True)
    inst = _tiny_trainer(telemetry=tele)
    inst.monitor = obs.ConvergenceMonitor(inst.sys, telemetry=tele,
                                          registry=reg)
    ms_inst = inst.run(2)
    obs.metrics.set_default(None)
    tele.close()

    leaves_a = jax.tree.leaves(plain.params)
    leaves_b = jax.tree.leaves(inst.params)
    assert len(leaves_a) == len(leaves_b)
    for a, b in zip(leaves_a, leaves_b):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for ma, mb in zip(ms_plain, ms_inst):
        assert ma.net_cost == mb.net_cost  # exact, not approx
        assert ma.n_selected == mb.n_selected
        assert ma.n_uploaded == mb.n_uploaded

    # and the instrumented run actually recorded everything — including
    # the v4 span instrumentation (nested solver spans + round roots)
    kinds = {type(e).__name__ for e in tele.events}
    assert {"StageEvent", "SolverEvent", "RoundEvent",
            "ProfileEvent", "SpanEvent"} <= kinds
    span_names = {e.name for e in tele.events
                  if isinstance(e, obs.SpanEvent)}
    assert "round" in span_names
    assert {"selection.gp", "selection.recover"} <= span_names
    assert reg.counter("feel_rounds_total").value() == 2.0
    assert inst.monitor.summary()["rounds"] == 2
