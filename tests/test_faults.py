"""Fault-injection + resilience layer (docs/robustness.md).

Covers: FaultPlan determinism/replay, the bit-identity invariant with
faults disabled, chaos-run determinism, the eps_k == 0 and all-dropped
aggregation guards, NaN quarantine, partial matching, the solver
fallback chain, and checkpoint/resume bit-identity.
"""
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import default_system, matching
from repro.core import joint as joint_mod
from repro.core import sample_round
from repro.data import SyntheticImages, non_iid_split
from repro.fed import (CHAOS_SPEC, FEELConfig, FEELTrainer, FaultPlan,
                       FaultSpec, ResilienceConfig, server)
from repro.models import cnn


# ----------------------------------------------------------------------
# FaultPlan: determinism, replay, spec round-trip
# ----------------------------------------------------------------------

def test_plan_same_spec_same_faults():
    a = FaultPlan(CHAOS_SPEC)
    b = FaultPlan(FaultSpec.from_dict(CHAOS_SPEC.to_dict()))
    for i in (0, 3, 17):
        ra, rb = a.for_round(i, 8), b.for_round(i, 8)
        assert np.array_equal(ra.dropout, rb.dropout)
        assert np.array_equal(ra.straggler, rb.straggler)
        assert np.array_equal(ra.delay_s, rb.delay_s)
        assert np.array_equal(ra.nan_upload, rb.nan_upload)
        assert ra.fail_matching == rb.fail_matching
        assert ra.fail_power == rb.fail_power


def test_plan_call_order_free():
    """Faults for round i must not depend on which rounds were queried
    before — this is what makes resume() replay exact faults."""
    a, b = FaultPlan(CHAOS_SPEC), FaultPlan(CHAOS_SPEC)
    ra = a.for_round(5, 6)           # fresh plan, round 5 first
    for i in range(5):
        b.for_round(i, 6)            # other plan walks 0..4 first
    rb = b.for_round(5, 6)
    assert np.array_equal(ra.dropout, rb.dropout)
    assert np.array_equal(ra.delay_s, rb.delay_s)
    assert a.retry_delay_s(5, 2, 1) == b.retry_delay_s(5, 2, 1)


def test_plan_window_and_zero_rate():
    spec = FaultSpec(seed=1, dropout_prob=1.0, start_round=2,
                     stop_round=4)
    plan = FaultPlan(spec)
    assert not plan.for_round(1, 4).any()
    assert plan.for_round(2, 4).dropout.all()
    assert not plan.for_round(4, 4).any()
    assert not FaultPlan(FaultSpec(seed=0)).for_round(0, 4).any()


def test_spec_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown FaultSpec"):
        FaultSpec.from_dict({"seed": 0, "nope": 1})


def test_disjoint_fault_classes():
    plan = FaultPlan(FaultSpec(seed=3, dropout_prob=0.5,
                               straggler_prob=0.9, nan_prob=0.9))
    for i in range(10):
        rf = plan.for_round(i, 16)
        assert not (rf.dropout & rf.straggler).any()
        assert not (rf.dropout & rf.nan_upload).any()
        assert np.all(rf.delay_s[~rf.straggler] == 0.0)


# ----------------------------------------------------------------------
# aggregation guards (server.py)
# ----------------------------------------------------------------------

def _sys_with_eps(eps):
    sys_ = default_system(K=len(eps), N=3, Q=2, D_hat=4)
    import dataclasses
    return dataclasses.replace(sys_, eps=jnp.asarray(eps, jnp.float32))


def test_eps_zero_guard_no_nan():
    sys_ = _sys_with_eps([0.0, 0.5, 0.9])
    alpha = jnp.asarray([1.0, 1.0, 0.0])
    w = server.ipw_weights(sys_, alpha)
    assert bool(jnp.all(jnp.isfinite(w)))
    assert float(w[0]) == 0.0       # eps=0 device contributes nothing
    grads = {"w": jnp.ones((3, 2))}
    g = server.aggregate_gradients(sys_, grads, alpha)
    assert bool(jnp.all(jnp.isfinite(g["w"])))


def test_renormalized_aggregation():
    sys_ = _sys_with_eps([0.5, 0.5, 0.5])
    grads = {"w": jnp.asarray([[2.0], [4.0], [8.0]])}
    alpha = jnp.asarray([1.0, 1.0, 0.0])
    g = server.aggregate_gradients(sys_, grads, alpha, renormalize=True)
    # equal weights on the two survivors -> plain mean of their grads
    np.testing.assert_allclose(np.asarray(g["w"]), [3.0], rtol=1e-6)
    zero = server.aggregate_gradients(sys_, grads, jnp.zeros(3),
                                      renormalize=True)
    assert float(jnp.abs(zero["w"]).sum()) == 0.0
    assert server.ipw_mass(sys_, jnp.zeros(3)) == 0.0


# ----------------------------------------------------------------------
# partial matching (core/matching.py) + fallback chain (core/joint.py)
# ----------------------------------------------------------------------

def test_partial_matching_reports_unmatched():
    """K > N*Q: capacity can't seat everyone; the leftovers must be an
    explicit outcome, not a silent break."""
    sys_ = default_system(K=7, N=2, Q=2, D_hat=4)   # capacity 4 < 7
    st = sample_round(jax.random.PRNGKey(0), sys_)
    alpha = jnp.ones((7,), jnp.float32)
    reg = obs.Registry()
    obs.metrics.set_default(reg)
    res = matching.swap_matching(sys_, st.h, alpha)
    assert res.unmatched.size == 7 - 4
    assert not res.feasible
    seated = np.flatnonzero(res.rho.sum(axis=1) > 0)
    assert np.intersect1d(seated, res.unmatched).size == 0
    rendered = reg.render()
    assert "feel_solver_infeasible_total" in rendered


def test_forced_solver_failures_fall_back():
    sys_ = default_system(K=6, N=3, Q=2, D_hat=4)
    st = sample_round(jax.random.PRNGKey(1), sys_)
    tele = obs.Telemetry()
    reg = obs.Registry()
    obs.metrics.set_default(reg)
    rf = types.SimpleNamespace(fail_matching=True, fail_power=True,
                               dropout=np.zeros(6, bool))
    dec = joint_mod.proposed_scheme(sys_, st, gp_steps=30, faults=rf,
                                    power_evaluator="ccp", telemetry=tele)
    assert dec.feasible                       # greedy fallback succeeded
    assert "matching->greedy" in dec.fallbacks
    assert "ccp->closed_form" in dec.fallbacks
    kinds = [e.kind for e in tele.events if isinstance(e, obs.FaultEvent)]
    assert "solver_fail" in kinds and "fallback" in kinds
    rendered = reg.render()
    assert 'feel_fallbacks_total{solver="matching",to="greedy"}' in rendered
    assert 'feel_faults_injected_total{kind="solver_fail"}' in rendered


def test_no_faults_no_fallbacks():
    sys_ = default_system(K=6, N=3, Q=2, D_hat=4)
    st = sample_round(jax.random.PRNGKey(1), sys_)
    dec = joint_mod.proposed_scheme(sys_, st, gp_steps=30)
    assert dec.fallbacks == ()
    assert dec.unmatched.size == 0


# ----------------------------------------------------------------------
# trainer-level: bit identity, chaos determinism, quarantine, resume
# ----------------------------------------------------------------------

def _build_trainer(faults=None, res=None, telemetry=None, K=4):
    train = SyntheticImages.make(240, side=10, seed=0)
    test = SyntheticImages.make(80, side=10, seed=1)
    fd = non_iid_split(train, test, K=K, per_device=40,
                       mislabel_prop=0.1, seed=0)
    sys_ = default_system(K=K, N=2, Q=2, D_hat=8)
    cfg = FEELConfig(d_hat=8, gp_steps=30, eval_every=100)
    cc = cnn.CNNConfig(side=10)
    params = cnn.init(jax.random.PRNGKey(0), cc)
    model = types.SimpleNamespace(features=cnn.features, apply=cnn.apply,
                                  loss_fn=cnn.loss_fn,
                                  accuracy=cnn.accuracy)
    return FEELTrainer(sys_, fd, model, params, cfg, telemetry=telemetry,
                       faults=faults, resilience=res)


def _params_equal(a, b):
    return all(np.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a.params),
                               jax.tree.leaves(b.params)))


@pytest.mark.slow
def test_disabled_faults_bit_identical():
    """faults with all-zero rates + resilience on must not perturb the
    trajectory by a single bit (the PR's acceptance invariant)."""
    plain = _build_trainer()
    plain.run(3)
    guarded = _build_trainer(faults=FaultSpec(seed=0),
                             res=ResilienceConfig())
    guarded.run(3)
    assert _params_equal(plain, guarded)


@pytest.mark.slow
def test_chaos_deterministic_and_finite():
    spec = FaultSpec(seed=2, dropout_prob=0.4, straggler_prob=0.4,
                     straggler_delay_s=0.5, nan_prob=0.3,
                     matching_fail_prob=0.3, power_fail_prob=0.3)
    a = _build_trainer(faults=spec, res=ResilienceConfig())
    ms = a.run(4)
    for leaf in jax.tree.leaves(a.params):
        assert bool(np.isfinite(np.asarray(leaf)).all())
    assert sum(m.n_dropped for m in ms) > 0
    b = _build_trainer(faults=spec, res=ResilienceConfig())
    b.run(4)
    assert _params_equal(a, b)


@pytest.mark.slow
def test_total_dropout_skips_updates():
    spec = FaultSpec(seed=0, dropout_prob=1.0)
    tr = _build_trainer(faults=spec, res=ResilienceConfig())
    init = [np.asarray(x).copy() for x in jax.tree.leaves(tr.params)]
    ms = tr.run(2)
    assert all(m.skipped_update for m in ms)
    assert all(m.n_uploaded == 0 for m in ms)
    final = jax.tree.leaves(tr.params)
    assert all(np.array_equal(a, b) for a, b in zip(init, final))


@pytest.mark.slow
def test_nan_uploads_trigger_quarantine():
    spec = FaultSpec(seed=0, nan_prob=1.0)
    tele = obs.Telemetry()
    tr = _build_trainer(faults=spec,
                        res=ResilienceConfig(quarantine_threshold=1,
                                             quarantine_rounds=2),
                        telemetry=tele)
    ms = tr.run(3)
    for leaf in jax.tree.leaves(tr.params):
        assert bool(np.isfinite(np.asarray(leaf)).all())
    kinds = [e.kind for e in tele.events if isinstance(e, obs.FaultEvent)]
    assert "nan_upload" in kinds
    assert "quarantine" in kinds
    assert any(m.n_quarantined > 0 for m in ms[1:])


@pytest.mark.slow
def test_checkpoint_resume_bit_identical(tmp_path):
    spec = FaultSpec(seed=5, dropout_prob=0.3, nan_prob=0.2)
    res = ResilienceConfig(checkpoint_every=2,
                           checkpoint_dir=str(tmp_path))
    full = _build_trainer(faults=spec, res=res)
    full.run(4)
    half = _build_trainer(faults=spec, res=res)
    half.run(2)                      # checkpoint written at round 2
    assert os.path.exists(os.path.join(str(tmp_path),
                                       "feel_ckpt.npz"))
    resumed = _build_trainer(faults=spec, res=res)
    assert resumed.resume() == 2
    resumed.run(4)
    assert _params_equal(full, resumed)


@pytest.mark.slow
def test_resolve_policy_runs():
    spec = FaultSpec(seed=1, dropout_prob=0.5)
    tr = _build_trainer(faults=spec,
                        res=ResilienceConfig(dropout_policy="resolve"))
    ms = tr.run(3)
    assert any("resolve_survivors" in m.fallbacks for m in ms)
    for leaf in jax.tree.leaves(tr.params):
        assert bool(np.isfinite(np.asarray(leaf)).all())
