"""Property-based equivalence suite for the batched solver layer (PR 10).

The batched paths are pure performance rewrites — each test here pins
one of them to its scalar/unbatched reference:

* ``swap_matching(mode="batched")`` replays the scalar first-improvement
  sweep move for move, so assignments, swap counts and sweep counts are
  *identical* (not merely objective-tied).
* the bucketed ``power._inner_solve`` pads the active set to a static
  bucket; padding slots are masked out of the objective, gradient and
  Hessian, so the Newton trajectory matches the effectively-unpadded
  solve (``pad_to=m``) bit for bit up to float tolerance.
* ``gradient_projection(device_chunk=...)`` runs Algorithm 4 over
  ``lax.map`` device blocks; the objective is separable per device so
  the iterates match the full-matrix path exactly.
* ``fed.client.batched_sigma`` fuses the per-device vmapped sigma into
  one flat forward pass + the row-norm kernel.

Runs under real ``hypothesis`` when installed, else under
tests/_hypothesis_stub.py (same API, seeded bounded examples).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

import strategies as strat
from repro.core import default_system, matching, power, selection


# ----------------------------------------------------- matching: batched

def _both_modes(sys_, h, alpha, **kw):
    rs = matching.swap_matching(sys_, h, alpha, mode="scalar", **kw)
    rb = matching.swap_matching(sys_, h, alpha, mode="batched", **kw)
    return rs, rb


def _assert_same_decisions(rs, rb):
    assert rs.mode == "scalar" and rb.mode == "batched"
    np.testing.assert_array_equal(rs.assign, rb.assign)
    np.testing.assert_array_equal(rs.rho, rb.rho)
    assert rs.swaps == rb.swaps
    assert rs.sweeps == rb.sweeps
    assert rs.feasible == rb.feasible
    np.testing.assert_array_equal(np.sort(rs.unmatched),
                                  np.sort(rb.unmatched))
    # equal assignments must price identically (inf == inf when the
    # closed-form power is infeasible for the final matching)
    if np.isinf(rs.cost) or np.isinf(rb.cost):
        assert np.isinf(rs.cost) and np.isinf(rb.cost)
    else:
        assert abs(rs.cost - rb.cost) <= 1e-6 * max(abs(rs.cost), 1.0)


@settings(max_examples=25, deadline=None)
@given(strat.matching_instance())
def test_batched_matching_replays_scalar_decisions(inst):
    sys_, h, alpha = inst
    _assert_same_decisions(*_both_modes(sys_, h, alpha))


@settings(max_examples=10, deadline=None)
@given(strat.matching_instance(max_k=6, max_n=3, max_q=2))
def test_batched_matching_equivalent_without_moves(inst):
    """allow_moves=False restricts the sweep to swaps only — the
    batched enumeration must honour the same restriction."""
    sys_, h, alpha = inst
    _assert_same_decisions(*_both_modes(sys_, h, alpha,
                                        allow_moves=False))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_batched_matching_equivalent_above_auto_threshold(seed):
    """A draw at K >= AUTO_BATCH_MIN — the regime auto actually routes
    to the batched sweep — still replays the scalar decisions."""
    K, N = matching.AUTO_BATCH_MIN + 8, 5
    rng = np.random.default_rng(seed)
    sys_ = default_system(K=K, N=N, Q=-(-K // N))
    h = rng.gamma(2.0, 1e-5, size=(K, N))
    alpha = np.ones(K)
    rs, rb = _both_modes(sys_, h, alpha)
    _assert_same_decisions(rs, rb)
    auto = matching.swap_matching(sys_, h, alpha, mode="auto")
    assert auto.mode == "batched"
    np.testing.assert_array_equal(auto.assign, rs.assign)


def test_auto_mode_dispatch():
    """auto = scalar below AUTO_BATCH_MIN available devices, batched at
    or above it (closed_form evaluator only)."""
    rng = np.random.default_rng(0)
    small = default_system(K=4, N=2, Q=2)
    res = matching.swap_matching(small, rng.gamma(2.0, 1e-5, size=(4, 2)),
                                 np.ones(4), mode="auto")
    assert res.mode == "scalar"
    K = matching.AUTO_BATCH_MIN
    big = default_system(K=K, N=8, Q=-(-K // 8))
    res = matching.swap_matching(big, rng.gamma(2.0, 1e-5, size=(K, 8)),
                                 np.ones(K), mode="auto")
    assert res.mode == "batched"


def test_mode_validation():
    sys_ = default_system(K=3, N=2, Q=2)
    h = np.full((3, 2), 1e-5)
    with pytest.raises(ValueError, match="unknown matching mode"):
        matching.swap_matching(sys_, h, np.ones(3), mode="vectorised")
    with pytest.raises(ValueError, match="closed_form"):
        matching.swap_matching(sys_, h, np.ones(3), evaluator="ccp",
                               mode="batched")
    # auto + ccp silently stays scalar (documented fallback)
    res = matching.swap_matching(sys_, h, np.ones(3), evaluator="ccp",
                                 mode="auto")
    assert res.mode == "scalar"


# ------------------------------------------- power: bucketed inner solve

def _ccp_inner_setup(seed):
    """A fixed-shape (K=6, N=3) subproblem so every example reuses one
    compiled Newton step; only the channel draw varies."""
    rng = np.random.default_rng(seed)
    sys_ = default_system(K=6, N=3, Q=2)
    h = rng.gamma(2.0, 1e-5, size=(6, 3))
    alpha = np.ones(6)
    res = matching.swap_matching(sys_, h, alpha)
    rho = jnp.asarray(res.rho, jnp.float32)
    h_j = jnp.asarray(h, jnp.float32)
    alpha_j = jnp.asarray(alpha, jnp.float32)
    p_cf, feas = power.closed_form_power(sys_, rho, h_j, alpha_j)
    if not (res.feasible and bool(jnp.all(feas))):
        return None
    active = rho * alpha_j[:, None]
    weaker = power._weaker(h_j, active)
    mask_k = (jnp.sum(active, axis=1) > 0).astype(jnp.float32) * alpha_j
    p0 = jnp.minimum(p_cf * 1.5, sys_.p_max[:, None] * rho * (1 - 1e-4))
    return sys_, p0, rho, h_j, alpha_j, weaker, mask_k


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_bucketed_inner_solve_matches_unpadded(seed):
    """The bucketed solve (pad to 8) equals the exact-size solve
    (pad_to=m): pad slots must contribute nothing to the barrier,
    gradient or Hessian."""
    setup = _ccp_inner_setup(seed)
    if setup is None:
        return
    sys_, p0, rho, h, alpha, weaker, mask_k = setup
    m = int(np.count_nonzero(np.asarray(rho * alpha[:, None]) > 0))
    p_bucket = power._inner_solve(sys_, p0, rho, h, alpha, weaker, mask_k)
    p_exact = power._inner_solve(sys_, p0, rho, h, alpha, weaker, mask_k,
                                 pad_to=m)
    assert power._bucket_size(m) >= m
    np.testing.assert_allclose(np.asarray(p_bucket), np.asarray(p_exact),
                               rtol=1e-6, atol=1e-9)


@pytest.mark.slow
def test_bucketed_ccp_cost_matches_closed_form():
    """End-to-end CCP through the bucketed inner solve still lands on
    the closed-form optimum of (28)."""
    rng = np.random.default_rng(3)
    sys_ = default_system(K=6, N=3, Q=2)
    h = rng.gamma(2.0, 1e-5, size=(6, 3))
    res = matching.swap_matching(sys_, h, np.ones(6))
    rho = jnp.asarray(res.rho, jnp.float32)
    h_j = jnp.asarray(h, jnp.float32)
    p_cf, _ = power.closed_form_power(sys_, rho, h_j, jnp.ones(6))
    cost_cf = float(jnp.sum(sys_.c[:, None] * rho * p_cf) * sys_.T)
    out = power.ccp_power(sys_, rho, h_j, jnp.ones(6))
    assert out.feasible
    cost = float(jnp.sum(sys_.c[:, None] * rho * out.p) * sys_.T)
    assert abs(cost - cost_cf) / cost_cf < 5e-3


# -------------------------------------------- selection: chunked GP path

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from((1, 3, 4)))
def test_chunked_gp_matches_full_matrix(seed, chunk):
    """device_chunk splits Alg. 4 into lax.map blocks; the objective is
    separable per device so the iterates are identical."""
    rng = np.random.default_rng(seed)
    sys_ = default_system(K=10, D_hat=32)
    sigma = jnp.asarray(rng.gamma(2.0, 1.0, size=(10, 16)), jnp.float32)
    mask = jnp.ones((10, 16), jnp.float32)
    full = selection.gradient_projection(sys_, sigma, mask, steps=40)
    chunked = selection.gradient_projection(sys_, sigma, mask, steps=40,
                                            device_chunk=chunk)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=1e-6, atol=1e-7)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_chunked_faithful_selection_same_binary_choice(seed):
    rng = np.random.default_rng(seed)
    sys_ = default_system(K=8, D_hat=24)
    sigma = jnp.asarray(rng.gamma(2.0, 1.0, size=(8, 12)), jnp.float32)
    mask = jnp.ones((8, 12), jnp.float32)
    full = selection.faithful_selection(sys_, sigma, mask, steps=40)
    chunked = selection.faithful_selection(sys_, sigma, mask, steps=40,
                                           device_chunk=3)
    np.testing.assert_array_equal(np.asarray(chunked), np.asarray(full))


# ------------------------------------------------- client: batched sigma

def test_batched_sigma_matches_vmapped_reference():
    """The fused (K*D) forward + row-norm-kernel sigma equals the
    per-device vmapped ``per_sample_sigma`` to float32 tolerance."""
    from repro.fed import client
    from repro.models import cnn

    cc = cnn.CNNConfig(side=8)
    params = cnn.init(jax.random.PRNGKey(0), cc)
    K, D = 4, 6
    images = jax.random.normal(jax.random.PRNGKey(1), (K, D, 8, 8))
    labels = jax.random.randint(jax.random.PRNGKey(2), (K, D), 0, 10)
    ref = jax.vmap(
        lambda im, lb: client.per_sample_sigma(params, im, lb,
                                               cnn.features))(images, labels)
    fused = client.batched_sigma(params, images, labels, cnn.features)
    assert fused.shape == (K, D)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=5e-6, atol=1e-8)
