"""Tests for the convergence monitor (repro.obs.monitor) and the
vectorized Lemma-3 bound it consumes (repro.core.convergence)."""
import numpy as np
import pytest

from repro import obs
from repro.core import convergence, default_system
from repro.obs import metrics


def _sys(D_hat=8):
    return default_system(K=4, N=3, Q=2, D_hat=D_hat)


def _clean_gaps(sys_, n, gap0=1.0, g_norm_sq=0.5, eta=0.1, delta=4.0,
                beta=1.0):
    """A trajectory that sits exactly on the Lemma-2 recursion."""
    gaps = [gap0]
    for _ in range(n - 1):
        gaps.append(float(convergence.one_round_bound_from_delta(
            sys_, gaps[-1], g_norm_sq, eta, beta, delta)))
    return gaps


# ----------------------------------------------------- bound violation

def test_clean_trajectory_raises_nothing():
    sys_ = _sys()
    mon = obs.ConvergenceMonitor(sys_, obs.MonitorConfig(beta=1.0),
                                 telemetry=obs.NULL, registry=metrics.NULL)
    for i, gap in enumerate(_clean_gaps(sys_, 10)):
        out = mon.observe_round(i, gap=gap, g_norm_sq=0.5, eta=0.1,
                                delta_obj=4.0)
        assert out == []
    assert mon.violations == []
    assert mon.counts() == {k: 0 for k in obs.monitor.VIOLATION_KINDS}
    # the theory tracked reality exactly
    assert mon.bound_gap_ratio() == pytest.approx(1.0)


def test_injected_bound_crossing_raises_exactly_one_violation(tmp_path):
    path = str(tmp_path / "mon.jsonl")
    sys_ = _sys()
    reg = metrics.Registry()
    tele = obs.Telemetry(path=path)
    mon = obs.ConvergenceMonitor(sys_, obs.MonitorConfig(beta=1.0),
                                 telemetry=tele, registry=reg)
    gaps = _clean_gaps(sys_, 6)
    gaps[3] = gaps[3] * 2.0  # inject: round 3 jumps past its bound
    for i, gap in enumerate(gaps):
        mon.observe_round(i, gap=gap, g_norm_sq=0.5, eta=0.1,
                          delta_obj=4.0)
    tele.close()

    assert [v.kind for v in mon.violations] == ["bound_violation"]
    v = mon.violations[0]
    assert v.round == 3
    assert v.value == pytest.approx(gaps[3])
    assert v.value > v.threshold
    assert mon.bound_gap_ratio() == pytest.approx(2.0, rel=1e-5)

    # the violation reached both sinks: trace event + metrics counter
    mev = [e for e in tele.events if isinstance(e, obs.MonitorEvent)]
    assert len(mev) == 1 and mev[0].kind == "bound_violation"
    assert mev[0].round == 3
    rec = [r for r in obs.load_trace(path) if r["ev"] == "monitor"]
    assert len(rec) == 1
    assert reg.counter("feel_monitor_violations_total").value(
        kind="bound_violation") == 1.0


def test_bound_rtol_tolerates_stochastic_wiggle():
    sys_ = _sys()
    mon = obs.ConvergenceMonitor(
        sys_, obs.MonitorConfig(beta=1.0, bound_rtol=0.5),
        telemetry=obs.NULL, registry=metrics.NULL)
    gaps = _clean_gaps(sys_, 5)
    gaps[2] *= 1.4  # within the 50% slack
    for i, gap in enumerate(gaps):
        mon.observe_round(i, gap=gap, g_norm_sq=0.5, eta=0.1,
                          delta_obj=4.0)
    assert mon.counts()["bound_violation"] == 0


# --------------------------------------------- divergence + stragglers

def test_gap_divergence_fires_once_per_episode():
    sys_ = _sys()
    mon = obs.ConvergenceMonitor(
        sys_, obs.MonitorConfig(divergence_window=3, bound_rtol=1e9),
        telemetry=obs.NULL, registry=metrics.NULL)
    gaps = [1.0, 1.1, 1.2, 1.3, 1.4, 1.5, 1.6]  # monotone rise
    for i, gap in enumerate(gaps):
        mon.observe_round(i, gap=gap, g_norm_sq=0.0, eta=0.1,
                          delta_obj=0.0)
    # fires on the transition into divergence, not on every round of it
    assert mon.counts()["gap_divergence"] == 1


def test_straggler_round_detected_against_median():
    sys_ = _sys()
    mon = obs.ConvergenceMonitor(
        sys_, obs.MonitorConfig(straggler_factor=3.0,
                                straggler_min_history=5, bound_rtol=1e9),
        telemetry=obs.NULL, registry=metrics.NULL)
    walls = [0.1] * 6 + [1.0]  # last round is 10x the median
    out = []
    for i, w in enumerate(walls):
        out += mon.observe_round(i, gap=1.0, g_norm_sq=0.0, eta=0.1,
                                 delta_obj=0.0, wall_s=w)
    stragglers = [v for v in out if v.kind == "straggler"]
    assert len(stragglers) == 1
    assert stragglers[0].round == 6
    assert stragglers[0].detail["what"] == "round"


def test_straggler_stage_timings():
    sys_ = _sys()
    mon = obs.ConvergenceMonitor(
        sys_, obs.MonitorConfig(straggler_factor=2.0,
                                straggler_min_history=3, bound_rtol=1e9),
        telemetry=obs.NULL, registry=metrics.NULL)
    for i in range(5):
        slow = 0.9 if i == 4 else 0.01
        mon.observe_round(i, gap=1.0, g_norm_sq=0.0, eta=0.1,
                          delta_obj=0.0,
                          stage_s={"sigma": 0.01, "power": slow})
    s = [v for v in mon.violations if v.kind == "straggler"]
    assert len(s) == 1 and s[0].detail["what"] == "power"


def test_summary_shape():
    sys_ = _sys()
    mon = obs.ConvergenceMonitor(sys_, telemetry=obs.NULL,
                                 registry=metrics.NULL)
    mon.observe_round(0, gap=1.0, g_norm_sq=0.5, eta=0.1, delta_obj=4.0)
    s = mon.summary()
    assert s["rounds"] == 1
    assert s["bound_gap_ratio"] is None  # needs two rounds
    assert s["final_gap"] == 1.0
    assert s["final_bound"] is not None
    assert set(s["violations"]) == set(obs.monitor.VIOLATION_KINDS)


# ------------------------------------------- Lemma 3: cumprod vs oracle

@pytest.mark.parametrize("n", [1, 2, 7, 40])
def test_multi_round_bound_matches_scalar_oracle(n):
    sys_ = _sys(D_hat=8)
    rng = np.random.default_rng(0)
    etas = rng.uniform(0.01, 0.2, n).tolist()
    deltas = rng.uniform(0.0, 10.0, n).tolist()
    fast = convergence.multi_round_bound(sys_, 2.0, 0.5, 1.5, etas, deltas)
    ref = convergence.multi_round_bound_ref(sys_, 2.0, 0.5, 1.5, etas,
                                            deltas)
    assert fast == pytest.approx(ref, rel=1e-5)


def test_multi_round_bound_edge_cases():
    sys_ = _sys()
    assert convergence.multi_round_bound(sys_, 3.0, 0.5, 1.0, [], []) == 3.0
    with pytest.raises(ValueError):
        convergence.multi_round_bound(sys_, 3.0, 0.5, 1.0, [0.1], [])


def test_monitor_tracks_lemma3_trajectory_when_mu_set():
    sys_ = _sys()
    mon = obs.ConvergenceMonitor(
        sys_, obs.MonitorConfig(beta=1.0, mu=0.5, bound_rtol=1e9),
        telemetry=obs.NULL, registry=metrics.NULL)
    for i, gap in enumerate(_clean_gaps(sys_, 4)):
        mon.observe_round(i, gap=gap, g_norm_sq=0.5, eta=0.1,
                          delta_obj=4.0)
    assert len(mon.multi_bounds) == 4
    ref = convergence.multi_round_bound_ref(
        sys_, mon.gaps[0], 0.5, 1.0, mon._etas, mon._deltas)
    assert mon.multi_bounds[-1] == pytest.approx(ref, rel=1e-5)
