"""Differential oracle tests for the solver layer (PR 10).

Small-instance ground truth, computed by exhaustive enumeration in
plain Python, pins the solvers' semantics independently of any solver
code path:

* matching (Alg. 2): for K <= 4, N <= 3 every capacity-feasible full
  assignment is enumerated and priced through ``closed_form_power``;
  the swap matching must be feasible whenever any assignment is, never
  beat the optimum, and stay within a bounded optimality gap of it
  (first-improvement local search over the swap+move neighbourhood).
* selection: the per-device Problem-4 objective is enumerated over all
  non-empty subsets; ``exact_selection`` must hit that minimum exactly
  and ``faithful_selection`` (Algs. 4+5) must stay within a bounded
  gap of it.
* feasibility invariants on every drawn instance: one RB per device,
  per-RB capacity, availability masking, rate constraint (16) and the
  power budget p <= p_max.
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

import strategies as strat
from repro.core import channel, delta, matching, power, selection

#: local-search optimality-gap bound for the tiny-instance oracle.  The
#: swap+move neighbourhood is not globally optimal in general; on K<=4
#: instances the observed gap is far below this (usually 0).
MATCHING_GAP = 0.5
#: Alg. 4+5 vs the exact prefix-scan optimum, relative to |optimum|.
SELECTION_GAP = 0.5


# ------------------------------------------------------ matching oracle

def _brute_force_matching(sys_, h, alpha):
    """Minimum upload cost over every capacity-feasible full assignment
    of the available devices (inf when none is power-feasible)."""
    avail = np.flatnonzero(alpha > 0)
    best = float("inf")
    for combo in itertools.product(range(sys_.N), repeat=avail.size):
        counts = np.bincount(combo, minlength=sys_.N)
        if np.any(counts > sys_.Q):
            continue
        rho = np.zeros((sys_.K, sys_.N), np.float32)
        rho[avail, list(combo)] = 1.0
        p, feas = power.closed_form_power(sys_, jnp.asarray(rho),
                                          jnp.asarray(h, jnp.float32),
                                          jnp.asarray(alpha, jnp.float32))
        if not bool(jnp.all(feas)):
            continue
        cost = float(jnp.sum(sys_.c[:, None] * jnp.asarray(rho) * p)
                     * sys_.T)
        best = min(best, cost)
    return best


@settings(max_examples=15, deadline=None)
@given(strat.matching_instance(max_k=4, max_n=3, max_q=3))
def test_matching_against_brute_force(inst):
    sys_, h, alpha = inst
    if sys_.N * sys_.Q < int(np.sum(alpha > 0)):
        return  # partial matchings have no full-assignment oracle
    brute = _brute_force_matching(sys_, h, alpha)
    res = matching.swap_matching(sys_, h, alpha)
    if not np.isfinite(brute):
        assert not res.feasible
        return
    assert res.feasible
    # a local optimum can never beat the global one...
    assert res.cost >= brute * (1 - 1e-9)
    # ...and must stay within the documented local-search gap of it
    assert res.cost <= brute * (1 + MATCHING_GAP)


@settings(max_examples=20, deadline=None)
@given(strat.matching_instance())
def test_matching_feasibility_invariants(inst):
    """Constraints (11)-(14), (16) and the power budget on every
    returned matching, feasible or not."""
    sys_, h, alpha = inst
    res = matching.swap_matching(sys_, h, alpha)
    rho = jnp.asarray(res.rho)
    # (11)-(14): binary, per-RB capacity Q, one RB per device, masking
    assert bool(channel.assignment_valid(sys_, rho, jnp.asarray(alpha)))
    # assign vector and rho agree; unmatched + assigned partition avail
    np.testing.assert_array_equal(
        res.assign >= 0, np.asarray(rho).sum(axis=1) > 0)
    avail = set(np.flatnonzero(alpha > 0).tolist())
    assigned = set(np.flatnonzero(res.assign >= 0).tolist())
    assert assigned <= avail
    assert assigned | set(res.unmatched.tolist()) == avail
    # powers live only on assigned slots
    p = jnp.asarray(res.p)
    assert bool(jnp.all(jnp.where(rho == 0, p == 0, True)))
    if res.feasible:
        # (16): every available device uploads its alpha_k * L bits
        ok = channel.upload_feasible(sys_, rho, p, jnp.asarray(h),
                                     jnp.asarray(alpha))
        assert bool(jnp.all(ok))
        # (17): power budget
        assert bool(jnp.all(jnp.sum(p, axis=1)
                            <= sys_.p_max * (1 + 1e-6)))


# ----------------------------------------------------- selection oracle

def _brute_force_selection(sys_, sigma, mask):
    """Per-device minimum of the Problem-4 objective over all non-empty
    subsets (the constraint set of ``exact_selection``)."""
    A = np.asarray(sys_.a_weights())
    lam = float(sys_.lam)
    q = np.asarray(sys_.q)
    sigma = np.asarray(sigma)
    total = 0.0
    for k in range(sys_.K):
        idx = np.flatnonzero(np.asarray(mask)[k] > 0)
        best = float("inf")
        for r in range(1, idx.size + 1):
            for sub in itertools.combinations(idx, r):
                obj = (lam * A[k] * float(np.mean(sigma[k, list(sub)]))
                       - (1.0 - lam) * q[k] * r)
                best = min(best, obj)
        total += best
    return total


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(strat.system_params(max_k=4), st.integers(2, 7),
       st.integers(0, 2**31 - 1))
def test_exact_selection_hits_brute_force_optimum(sys_, J, seed):
    rng = np.random.default_rng(seed)
    sigma = jnp.asarray(rng.gamma(2.0, 1.0, size=(sys_.K, J)), jnp.float32)
    mask = jnp.ones((sys_.K, J), jnp.float32)
    brute = _brute_force_selection(sys_, sigma, mask)
    out = selection.exact_selection(sys_, sigma, mask)
    obj = float(delta.selection_only_objective(sys_, out, sigma))
    assert obj <= brute + 1e-5 * max(abs(brute), 1.0)
    assert obj >= brute - 1e-5 * max(abs(brute), 1.0)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(strat.system_params(max_k=4), st.integers(3, 7),
       st.integers(0, 2**31 - 1))
def test_faithful_selection_bounded_gap_to_exact(sys_, J, seed):
    """Algs. 4+5 vs the global optimum: never better, gap bounded."""
    rng = np.random.default_rng(seed)
    sigma = jnp.asarray(rng.gamma(2.0, 1.0, size=(sys_.K, J)), jnp.float32)
    mask = jnp.ones((sys_.K, J), jnp.float32)
    d_exact = selection.exact_selection(sys_, sigma, mask)
    d_faith = selection.faithful_selection(sys_, sigma, mask, steps=200)
    obj_e = float(delta.selection_only_objective(sys_, d_exact, sigma))
    obj_f = float(delta.selection_only_objective(sys_, d_faith, sigma))
    assert obj_f >= obj_e - 1e-5 * max(abs(obj_e), 1.0)
    assert obj_f - obj_e <= SELECTION_GAP * max(abs(obj_e), 1e-6)
    # both are valid selections: binary, inside the mask
    for d in (d_exact, d_faith):
        arr = np.asarray(d)
        assert set(np.unique(arr)) <= {0.0, 1.0}
        assert np.all(arr <= np.asarray(mask))
