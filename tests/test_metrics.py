"""Tests for the repro.obs.metrics registry: instrument semantics,
Prometheus text exposition, snapshot round-trip through the telemetry
trace, and the default-registry install pattern."""
import json
import re

import pytest

from repro import obs
from repro.obs import metrics


# --------------------------------------------------------- instruments

def test_counter_inc_value_and_labels():
    reg = metrics.Registry()
    c = reg.counter("feel_calls_total", "calls")
    c.inc()
    c.inc(2.5)
    c.inc(1, method="ccp")
    assert c.value() == 3.5
    assert c.value(method="ccp") == 1.0
    assert c.value(method="other") == 0.0
    # get-or-create hands back the same family
    assert reg.counter("feel_calls_total") is c


def test_counter_rejects_negative_increments():
    c = metrics.Registry().counter("c_total")
    with pytest.raises(ValueError):
        c.inc(-1.0)


def test_gauge_set_and_inc():
    g = metrics.Registry().gauge("g")
    g.set(5.0)
    g.set(2.0)
    assert g.value() == 2.0
    g.inc(-0.5)  # gauges may go down
    assert g.value() == 1.5


def test_histogram_observe_count_sum_quantile():
    h = metrics.Registry().histogram("h_seconds",
                                     buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count() == 5
    assert h.sum() == pytest.approx(5.605)
    # quantile returns the upper bound of the containing bucket
    assert h.quantile(0.5) == 0.1
    assert h.quantile(0.99) == 1.0  # +Inf bucket -> largest finite bound
    assert h.quantile(0.5, stage="x") == 0.0  # unseen labels


def test_histogram_requires_sorted_buckets():
    reg = metrics.Registry()
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(1.0, 0.1))
    with pytest.raises(ValueError):
        reg.histogram("bad2", buckets=())


def test_registry_rejects_kind_mismatch_and_bad_names():
    reg = metrics.Registry()
    reg.counter("x_total")
    with pytest.raises(ValueError):
        reg.gauge("x_total")
    reg.gauge("g")
    with pytest.raises(ValueError):
        reg.counter("g")  # Gauge subclasses Counter; still rejected
    with pytest.raises(ValueError):
        reg.counter("0bad name")


# ---------------------------------------------------------- exposition

_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? \S+$")


def test_render_is_valid_prometheus_text_exposition():
    reg = metrics.Registry()
    reg.counter("feel_rounds_total", "rounds run").inc(3)
    reg.gauge("feel_cost", 'net "cost"\nnow').set(-1.25)
    h = reg.histogram("feel_lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05, stage="sigma")
    h.observe(2.0, stage="sigma")
    text = reg.render()

    lines = text.strip().split("\n")
    for line in lines:
        if line.startswith("# HELP") or line.startswith("# TYPE"):
            continue
        assert _SAMPLE_RE.match(line), f"malformed sample line: {line!r}"
    assert "# TYPE feel_rounds_total counter" in lines
    assert "feel_rounds_total 3" in lines
    # HELP text is escaped (no raw newlines / quotes break the format)
    assert r"# HELP feel_cost net \"cost\"\nnow" in lines
    assert "feel_cost -1.25" in lines
    # histogram: cumulative le buckets + sum + count
    assert 'feel_lat_seconds_bucket{stage="sigma",le="0.1"} 1' in lines
    assert 'feel_lat_seconds_bucket{stage="sigma",le="1.0"} 1' in lines
    assert 'feel_lat_seconds_bucket{stage="sigma",le="+Inf"} 2' in lines
    assert 'feel_lat_seconds_sum{stage="sigma"} 2.05' in lines
    assert 'feel_lat_seconds_count{stage="sigma"} 2' in lines


def test_snapshot_render_roundtrip():
    reg = metrics.Registry()
    reg.counter("c_total", "c").inc(2, method="a")
    reg.gauge("g", "g").set(7.5)
    reg.histogram("h_seconds", "h", buckets=(0.5,)).observe(0.25)
    snap = reg.snapshot()
    # snapshot is plain JSON
    snap2 = json.loads(json.dumps(snap))
    assert metrics.render_snapshot(snap2) == reg.render()


# ------------------------------------------------- trace + CLI plumbing

def test_metrics_event_flows_through_telemetry(tmp_path):
    path = str(tmp_path / "t.jsonl")
    reg = metrics.Registry()
    reg.counter("feel_rounds_total", "rounds").inc(4)
    with obs.Telemetry(path=path) as tele:
        tele.emit(reg.snapshot_event(round=3))

    records = obs.load_trace(path)
    assert records[-1]["ev"] == "metrics"
    e = obs.parse_record(records[-1])
    assert isinstance(e, obs.MetricsEvent)
    assert e.round == 3
    assert metrics.render_snapshot(e.families) == reg.render()


def test_metrics_cli_renders_last_snapshot(tmp_path, capsys):
    path = str(tmp_path / "t.jsonl")
    reg = metrics.Registry()
    with obs.Telemetry(path=path) as tele:
        reg.counter("feel_rounds_total", "rounds").inc()
        tele.emit(reg.snapshot_event(round=0))
        reg.counter("feel_rounds_total").inc()
        tele.emit(reg.snapshot_event(round=1))  # cumulative: last wins

    metrics.main([path])
    out = capsys.readouterr().out
    assert "# TYPE feel_rounds_total counter" in out
    assert "feel_rounds_total 2" in out


def test_metrics_cli_errors_on_trace_without_metrics(tmp_path):
    path = str(tmp_path / "empty.jsonl")
    with obs.Telemetry(path=path) as tele:
        tele.begin_round(0)
        tele.round_end(wall_s=0.0, net_cost=0.0, delta_obj=0.0,
                       n_selected=0, n_uploaded=0, feasible=True)
    with pytest.raises(SystemExit):
        metrics.main([path])


# ----------------------------------------------------- default pattern

def test_null_registry_is_default_and_noop():
    assert metrics.get_default() is metrics.NULL
    assert metrics.NULL.enabled is False
    # instruments are shared no-ops; nothing raises, nothing records
    metrics.NULL.counter("x").inc(5)
    metrics.NULL.gauge("y").set(1.0)
    metrics.NULL.histogram("z").observe(0.1)
    assert metrics.NULL.snapshot() == []
    assert metrics.NULL.render() == ""
    assert metrics.NULL.snapshot_event().families == []


def test_set_default_install_resolve_and_reset():
    reg = metrics.Registry()
    metrics.set_default(reg)
    try:
        assert metrics.get_default() is reg
        assert metrics.resolve(None) is reg
        other = metrics.Registry()
        assert metrics.resolve(other) is other
    finally:
        metrics.set_default(None)
    assert metrics.get_default() is metrics.NULL


def test_timed_stage_mirrors_into_default_registry():
    reg = metrics.Registry()
    metrics.set_default(reg)
    tele = obs.Telemetry()
    with tele.stage("sigma"):
        pass
    metrics.set_default(None)
    h = reg.histogram("feel_stage_seconds")
    assert h.count(stage="sigma") == 1

    # without an installed registry nothing is recorded
    tele2 = obs.Telemetry()
    with tele2.stage("sigma"):
        pass
    assert h.count(stage="sigma") == 1


def test_registry_reset_clears_families():
    reg = metrics.Registry()
    reg.counter("c_total").inc()
    reg.reset()
    assert reg.snapshot() == []
    assert reg.counter("c_total").value() == 0.0
