"""Deeper model correctness: decode-vs-prefill consistency, windowed
attention exactness, GQA layout, M-RoPE, MoE routing, recurrent-state
equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import init_model, make_cache, make_decode_step, \
    make_forward, make_prefill_step
from repro.models.layers import (apply_rope, causal_attend,
                                 local_attend_chunked)
from repro.models.moe import moe_ffn


def _full_logits(cfg, params, batch):
    logits, _, _ = make_forward(cfg)(params, batch)
    return np.asarray(logits, np.float32)


@pytest.mark.parametrize("arch", ["llama3_2-3b", "gemma3-12b",
                                  "falcon-mamba-7b", "recurrentgemma-9b",
                                  "deepseek-v3-671b", "musicgen-medium"])
def test_decode_matches_full_forward(arch):
    """prefill(t[:n]) then decode t[n], t[n+1]... reproduces the full
    forward's next-token logits — the cache path is exact."""
    # float32 so jit-vs-eager fusion noise (bf16) can't mask real bugs
    cfg = dataclasses.replace(smoke_config(arch), remat=False,
                              dtype="float32")
    B, S, n_pre = 2, 12, 8
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)

    if cfg.modality == "audio":
        toks = jax.random.randint(key, (B, cfg.n_codebooks, S), 0,
                                  cfg.vocab)
        full_batch = {"tokens": toks, "labels": toks}
        pre_batch = {"tokens": toks[..., :n_pre]}
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        full_batch = {"tokens": toks, "labels": toks}
        pre_batch = {"tokens": toks[:, :n_pre]}

    full = _full_logits(cfg, params, full_batch)  # (B, S, [C,] V)

    logits_p, cache = jax.jit(make_prefill_step(cfg))(params, pre_batch)
    grown = make_cache(cfg, B, S)

    def graft(dst, src):
        if dst.shape == src.shape:
            return src
        pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src, pad).astype(dst.dtype)

    cache = jax.tree.map(graft, grown, cache)

    # prefill's last-position logits == full forward at n_pre-1
    np.testing.assert_allclose(np.asarray(logits_p[:, -1], np.float32),
                               full[:, n_pre - 1], atol=2e-2, rtol=2e-2)

    decode = jax.jit(make_decode_step(cfg))
    for t in range(n_pre, S):
        if cfg.modality == "audio":
            db = {"tokens": toks[..., t:t + 1],
                  "cache_index": jnp.int32(t)}
        else:
            db = {"tokens": toks[:, t:t + 1], "cache_index": jnp.int32(t)}
        logits_d, cache = decode(params, cache, db)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, -1], np.float32), full[:, t],
            atol=3e-2, rtol=3e-2)


def test_local_attention_equals_full_when_window_covers():
    key = jax.random.PRNGKey(0)
    B, S, H, D = 2, 48, 4, 16
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D))
               for i in range(3))
    full = causal_attend(q, k, v)
    local = local_attend_chunked(q, k, v, window=S)
    np.testing.assert_allclose(np.asarray(local), np.asarray(full),
                               atol=1e-5)


def test_local_attention_equals_masked_reference():
    key = jax.random.PRNGKey(1)
    B, S, H, D, W = 1, 37, 2, 8, 8
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D))
               for i in range(3))
    got = local_attend_chunked(q, k, v, window=W)
    want = causal_attend(q, k, v, window=W)  # independent mask impl
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_gqa_equals_repeated_kv():
    key = jax.random.PRNGKey(2)
    B, S, H, Hk, D = 2, 24, 8, 2, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hk, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hk, D))
    got = causal_attend(q, k, v)
    # reference: repeat kv heads and run MHA
    rep = H // Hk
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    want = causal_attend(q, kr, vr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_mrope_sections_differ_by_axis():
    """M-RoPE: different (t,h,w) position ids rotate different pair
    sections; equal ids across sections == standard rope."""
    B, S, H, D = 1, 6, 2, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    pos1d = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos3d_same = jnp.broadcast_to(jnp.arange(S)[None, None], (B, 3, S))
    same = apply_rope(x, pos3d_same, 1e4, 1.0, (4, 6, 6))
    std = apply_rope(x, pos1d, 1e4, 1.0)
    np.testing.assert_allclose(np.asarray(same), np.asarray(std),
                               atol=1e-5)
    pos3d_diff = pos3d_same.at[:, 1].add(5)
    diff = apply_rope(x, pos3d_diff, 1e4, 1.0, (4, 6, 6))
    assert not np.allclose(np.asarray(diff), np.asarray(std), atol=1e-3)


def test_partial_rope_preserves_tail_dims():
    B, S, H, D = 1, 4, 1, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    out = apply_rope(x, jnp.arange(S)[None], 1e4, fraction=0.25)
    np.testing.assert_allclose(np.asarray(out[..., 4:]),
                               np.asarray(x[..., 4:]), atol=1e-6)
    assert not np.allclose(np.asarray(out[..., :4]),
                           np.asarray(x[..., :4]), atol=1e-4)


def test_moe_routing_mass_and_aux():
    cfg = smoke_config("deepseek-v3-671b")
    from repro.models.moe import init_moe
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_ffn(cfg, p, x)
    assert y.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 0
    # capacity large enough here -> every token processed by topk experts;
    # output must differ from zero and react to input scaling
    y2, _ = moe_ffn(cfg, p, x * 2.0)
    assert not np.allclose(np.asarray(y), 0.0)
    assert not np.allclose(np.asarray(y), np.asarray(y2))


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "recurrentgemma-9b"])
def test_recurrent_prefill_state_equals_stepwise(arch):
    """Prefill's final recurrent state == running decode token by token."""
    cfg = dataclasses.replace(smoke_config(arch), remat=False,
                              dtype="float32")
    B, S = 1, 6
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    _, cache_pre = jax.jit(make_prefill_step(cfg))(params,
                                                   {"tokens": toks})
    # step-by-step: prefill 1 token then decode the rest
    _, cache_step = jax.jit(make_prefill_step(cfg))(
        params, {"tokens": toks[:, :1]})
    grown = make_cache(cfg, B, S)

    def graft(dst, src):
        if dst.shape == src.shape:
            return src
        pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src, pad).astype(dst.dtype)

    cache_step = jax.tree.map(graft, grown, cache_step)
    decode = jax.jit(make_decode_step(cfg))
    for t in range(1, S):
        _, cache_step = decode(params, cache_step,
                               {"tokens": toks[:, t:t + 1],
                                "cache_index": jnp.int32(t)})

    def leaves_named(c):
        return {"/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                         for q in path): leaf
                for path, leaf in
                jax.tree_util.tree_flatten_with_path(c)[0]}

    pre, step = leaves_named(cache_pre), leaves_named(cache_step)
    for name in pre:
        if name.endswith("/h"):  # recurrent states must agree
            np.testing.assert_allclose(
                np.asarray(pre[name], np.float32),
                np.asarray(step[name], np.float32), atol=3e-2, rtol=3e-2)


def test_mla_absorbed_equals_naive_decode():
    """The absorbed decode path (50x FLOP win, EXPERIMENTS.md §Perf A)
    must be numerically identical to the naive latent re-expansion."""
    cfg = dataclasses.replace(smoke_config("deepseek-v2-236b"),
                              remat=False, dtype="float32")
    B, S = 2, 10
    key = jax.random.PRNGKey(0)
    from repro.models import init_model as _init
    params = _init(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    _, cache = jax.jit(make_prefill_step(cfg))(params, {"tokens": toks})
    grown = make_cache(cfg, B, S + 2)

    def graft(dst, src):
        if dst.shape == src.shape:
            return src
        pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src, pad).astype(dst.dtype)

    cache = jax.tree.map(graft, grown, cache)
    db = {"tokens": toks[:, :1], "cache_index": jnp.int32(S)}
    naive, _ = jax.jit(make_decode_step(cfg, mla_absorbed=False))(
        params, cache, db)
    absorbed, _ = jax.jit(make_decode_step(cfg, mla_absorbed=True))(
        params, cache, db)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(absorbed),
                               atol=1e-4, rtol=1e-4)
