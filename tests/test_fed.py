"""FEEL runtime tests: Lemma-1 unbiasedness, selection behaviour on
mislabeled data, an end-to-end round, and the in-train FEEL step."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import convergence, default_system
from repro.data import SyntheticImages, non_iid_split
from repro.fed import FEELConfig, FEELTrainer, per_sample_sigma
from repro.fed.server import aggregate_gradients
from repro.models import cnn


def test_aggregation_unbiased_lemma1():
    """Monte-Carlo check of Lemma 1: E[g_hat] == mean local gradient."""
    sys_ = default_system(K=6, N=3, Q=2, D_hat=4)
    key = jax.random.PRNGKey(0)
    grads = jax.random.normal(key, (6, 10))  # (K, P) fixed local grads
    truth = jnp.einsum("k,kp->p", sys_.D_hat / sys_.D_hat_total, grads)
    acc = jnp.zeros(10)
    M = 4000
    for i in range(M):
        a = (jax.random.uniform(jax.random.fold_in(key, i), (6,))
             < sys_.eps).astype(jnp.float32)
        acc = acc + aggregate_gradients(sys_, grads, a)
    err = float(jnp.max(jnp.abs(acc / M - truth)))
    scale = float(jnp.max(jnp.abs(truth)))
    assert err < 0.12 * max(scale, 1.0), (err, scale)


def test_sigma_full_vs_last_layer_ranking():
    """Both sigma modes must rank a mislabeled sample above a clean one
    once the model fits the clean data."""
    cc = cnn.CNNConfig(side=12)
    params = cnn.init(jax.random.PRNGKey(0), cc)
    data = SyntheticImages.make(64, side=12, seed=0)
    imgs = jnp.asarray(data.images)
    labels = jnp.asarray(data.labels)
    # overfit a few steps so predictions align with clean labels
    from repro import optim
    opt = optim.adam(3e-3)
    st = opt.init(params)
    step = jax.jit(lambda p, s: _sgd_step(p, s, imgs, labels, opt))
    for _ in range(60):
        params, st = step(params, st)
    bad_labels = labels.at[:8].set((labels[:8] + 1) % 10)
    for method in ("last_layer", "full"):
        sigma = per_sample_sigma(params, imgs[:16], bad_labels[:16],
                                 features_fn=cnn.features, method=method,
                                 loss_fn=cnn.loss_fn)
        bad = float(jnp.mean(sigma[:8]))
        good = float(jnp.mean(sigma[8:16]))
        assert bad > good, (method, bad, good)


def _sgd_step(params, st, imgs, labels, opt):
    g = jax.grad(cnn.loss_fn)(params, imgs, labels)
    upd, st = opt.update(g, st, params)
    from repro.optim import apply_updates
    return apply_updates(params, upd), st


@pytest.mark.slow
def test_feel_round_end_to_end():
    train = SyntheticImages.make(600, side=12, seed=0)
    test = SyntheticImages.make(200, side=12, seed=1)
    fd = non_iid_split(train, test, K=6, per_device=60,
                       mislabel_prop=0.1, seed=0)
    sys_ = default_system(K=6, N=3, Q=2, D_hat=16)
    cfg = FEELConfig(d_hat=16, gp_steps=80, eval_every=3)
    cc = cnn.CNNConfig(side=12)
    params = cnn.init(jax.random.PRNGKey(0), cc)
    model = types.SimpleNamespace(features=cnn.features, apply=cnn.apply,
                                  loss_fn=cnn.loss_fn,
                                  accuracy=cnn.accuracy)
    tr = FEELTrainer(sys_, fd, model, params, cfg)
    ms = tr.run(4)
    assert all(np.isfinite(m.net_cost) for m in ms)
    assert all(m.n_selected >= 6 for m in ms)  # >=1 per device (25)
    assert ms[0].test_acc is not None


@pytest.mark.slow
def test_fedavg_variant_runs():
    train = SyntheticImages.make(300, side=12, seed=0)
    test = SyntheticImages.make(100, side=12, seed=1)
    fd = non_iid_split(train, test, K=4, per_device=40,
                       mislabel_prop=0.1, seed=0)
    sys_ = default_system(K=4, N=2, Q=2, D_hat=10)
    cfg = FEELConfig(d_hat=10, local_steps=3, gp_steps=50, eval_every=10)
    cc = cnn.CNNConfig(side=12)
    params = cnn.init(jax.random.PRNGKey(0), cc)
    model = types.SimpleNamespace(features=cnn.features, apply=cnn.apply,
                                  loss_fn=cnn.loss_fn,
                                  accuracy=cnn.accuracy)
    tr = FEELTrainer(sys_, fd, model, params, cfg)
    ms = tr.run(2)
    assert np.isfinite(ms[-1].net_cost)


def test_feel_train_step_integration():
    """The in-jit FEEL integration: selection reduces to the exact
    solver's output, availability masks clients."""
    from repro.configs import smoke_config
    from repro.models import FeelIntegration, init_model, make_train_step
    from repro import optim
    cfg = smoke_config("llama3_2-3b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(1e-3)
    st = opt.init(params)
    feel = FeelIntegration(n_clients=4)
    step = jax.jit(make_train_step(cfg, opt, feel=feel))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks,
             "alpha": jnp.ones((4,), jnp.float32)}
    p2, st2, m = step(params, st, batch)
    assert np.isfinite(float(m["loss"]))
    assert 0 < float(m["selected_frac"]) <= 1.0
    # all clients unavailable -> zero gradient signal -> params unchanged
    batch0 = dict(batch, alpha=jnp.zeros((4,), jnp.float32))
    p3, _, m0 = step(params, st, batch0)
    assert float(m0["loss"]) == 0.0


def test_lemma2_bound_on_quadratic():
    """On a strongly-convex quadratic with exact per-sample gradients,
    the Lemma-2 RHS is a valid upper bound of the expected next gap."""
    key = jax.random.PRNGKey(0)
    K, J, P = 4, 6, 5
    sys_ = default_system(K=K, N=2, Q=2, D_hat=J)
    A = jax.random.normal(key, (K, J, P)) * 0.5  # per-sample features

    def per_sample_grad(w):
        # l_kj = 0.5 ||w - a_kj||^2 -> grad = w - a_kj ; beta = 1
        return w[None, None, :] - A

    w = jnp.ones(P) * 2.0
    w_star = jnp.mean(A.reshape(-1, P), axis=0)

    def L(w):
        return 0.5 * float(jnp.mean(jnp.sum(
            (w[None, None] - A) ** 2, axis=-1)))

    eta, beta = 0.3, 1.0  # larger eta -> larger bound slack vs MC noise
    g = per_sample_grad(w)
    sigma = jnp.sum(g * g, axis=-1)  # (K, J)
    delta_sel = jnp.ones((K, J))
    gap = L(w) - L(w_star)
    g_true = jnp.mean(g.reshape(-1, P), axis=0)
    bound = convergence.one_round_bound(
        sys_, jnp.asarray(gap), jnp.sum(g_true ** 2), jnp.asarray(eta),
        jnp.asarray(beta), delta_sel, sigma)
    # Monte-Carlo the actual expected gap after one aggregated step
    gaps = []
    for i in range(1000):
        a = (jax.random.uniform(jax.random.fold_in(key, i), (K,))
             < sys_.eps).astype(jnp.float32)
        local = jnp.mean(g, axis=1)  # (K, P) full selection
        ghat = aggregate_gradients(sys_, local, a)
        gaps.append(L(w - eta * ghat) - L(w_star))
    se = float(np.std(gaps) / np.sqrt(len(gaps)))
    assert np.mean(gaps) <= float(bound) + 3 * se
