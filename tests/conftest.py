"""Shared test fixtures."""
import pytest

from repro import obs


@pytest.fixture(autouse=True)
def _reset_obs_defaults():
    """The telemetry sink and metrics registry are process-wide
    defaults; a test that installs one must not leak it into the next
    test, so both are reset after every test unconditionally."""
    yield
    obs.set_default(None)
    obs.metrics.set_default(None)
