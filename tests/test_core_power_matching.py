"""Tests for the NOMA channel, power allocation (Alg. 3) and matching (Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional test extra; property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import channel, default_system, matching, power, sample_round


def make_round(seed=0, K=10, N=5, Q=2):
    sys_ = default_system(K=K, N=N, Q=Q, D_hat=16)
    st_ = sample_round(jax.random.PRNGKey(seed), sys_)
    return sys_, st_


# ----------------------------------------------------------------- channel

def test_rate_monotone_in_power_no_interference():
    sys_, st_ = make_round()
    rho = np.zeros((sys_.K, sys_.N), np.float32)
    rho[0, 0] = 1.0
    p1 = np.zeros_like(rho); p1[0, 0] = 1.0
    p2 = np.zeros_like(rho); p2[0, 0] = 2.0
    r1 = float(channel.rate_per_device(sys_, jnp.asarray(rho),
                                       jnp.asarray(p1), st_.h)[0])
    r2 = float(channel.rate_per_device(sys_, jnp.asarray(rho),
                                       jnp.asarray(p2), st_.h)[0])
    assert r2 > r1 > 0


def test_sic_interference_ordering():
    """Stronger-gain device sees the weaker one as interference, not
    vice versa (paper's SIC decode order)."""
    sys_, st_ = make_round(K=2, N=1, Q=2)
    h = np.array([[1e-5], [2e-5]], np.float32)  # device 1 stronger
    rho = np.ones((2, 1), np.float32)
    p = np.ones((2, 1), np.float32)
    I = channel.interference(jnp.asarray(rho), jnp.asarray(p),
                             jnp.asarray(h), sys_.N0)
    N0 = float(sys_.N0)
    assert np.isclose(float(I[0, 0]), N0, rtol=1e-6)          # weak: clean
    assert np.isclose(float(I[1, 0]), N0 + 1e-5, rtol=1e-5)   # strong: hit


# ------------------------------------------------------------------- power

def test_closed_form_hits_rate_targets_exactly():
    sys_, st_ = make_round(seed=4)
    res = matching.swap_matching(sys_, st_.h, st_.alpha)
    p, feas = power.closed_form_power(sys_, jnp.asarray(res.rho), st_.h,
                                      st_.alpha)
    assert bool(jnp.all(feas))
    rates = channel.rate_per_device(sys_, jnp.asarray(res.rho), p, st_.h)
    need = np.asarray(st_.alpha) * float(sys_.L) / float(sys_.T)
    got = np.asarray(rates)
    active = (np.asarray(res.rho).sum(1) > 0)
    # every matched available device hits its target (tight constraints)
    assert np.allclose(got[active], need[active], rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_closed_form_is_feasible_and_minimal(seed):
    """Any uniform scale-down of the closed-form powers violates (16)."""
    sys_, st_ = make_round(seed=seed % 2**31)
    res = matching.swap_matching(sys_, st_.h, st_.alpha)
    if not res.feasible:
        return
    rho = jnp.asarray(res.rho)
    p, _ = power.closed_form_power(sys_, rho, st_.h, st_.alpha)
    ok = channel.upload_feasible(sys_, rho, p, st_.h, st_.alpha)
    assert bool(jnp.all(ok))
    shrunk = channel.upload_feasible(sys_, rho, p * 0.95, st_.h, st_.alpha,
                                     rtol=0.0)
    active = np.asarray(rho).sum(1) > 0
    assert not bool(jnp.all(jnp.asarray(shrunk)[active]))


@pytest.mark.slow
def test_ccp_converges_to_closed_form():
    """Algorithm 3 (CCP) reaches the exact optimum of (28)."""
    sys_, st_ = make_round(seed=7)
    res = matching.swap_matching(sys_, st_.h, st_.alpha)
    rho = jnp.asarray(res.rho)
    p_cf, _ = power.closed_form_power(sys_, rho, st_.h, st_.alpha)
    cost_cf = float(jnp.sum(sys_.c[:, None] * rho * p_cf) * sys_.T)
    out = power.ccp_power(sys_, rho, st_.h, st_.alpha)
    assert out.feasible
    cost_ccp = float(jnp.sum(sys_.c[:, None] * rho * out.p) * sys_.T)
    assert abs(cost_ccp - cost_cf) / cost_cf < 5e-3
    # trajectory is (weakly) decreasing after the first iterate
    traj = out.trajectory
    assert all(traj[i + 1] <= traj[i] * (1 + 1e-6)
               for i in range(len(traj) - 1))


@pytest.mark.slow
def test_ccp_robust_to_initial_point():
    """Paper Fig. 3: identical objective from different feasible inits."""
    sys_, st_ = make_round(seed=9)
    res = matching.swap_matching(sys_, st_.h, st_.alpha)
    rho = jnp.asarray(res.rho)
    p_cf, _ = power.closed_form_power(sys_, rho, st_.h, st_.alpha)
    finals = []
    for scale in (1.2, 2.0, 4.0):
        p0 = jnp.minimum(p_cf * scale,
                         sys_.p_max[:, None] * rho * (1 - 1e-4))
        out = power.ccp_power(sys_, rho, st_.h, st_.alpha, p0=p0)
        finals.append(out.trajectory[-1])
    assert max(finals) - min(finals) < 5e-3 * max(finals)


# ---------------------------------------------------------------- matching

def test_matching_respects_constraints():
    for seed in range(5):
        sys_, st_ = make_round(seed=seed)
        res = matching.swap_matching(sys_, st_.h, st_.alpha)
        rho = jnp.asarray(res.rho)
        assert bool(channel.assignment_valid(sys_, rho, st_.alpha))


def test_matching_beats_or_ties_naive_assignments():
    """Swap matching should never end up worse than the greedy baselines."""
    from repro.core import joint
    sys_, st_ = make_round(seed=11)
    res = matching.swap_matching(sys_, st_.h, st_.alpha)
    for idx in (3, 4):  # all-data baselines share the matching cost shape
        bl = joint.baseline_scheme(sys_, st_, idx)
        if not bl.feasible:
            continue
        p_bl = jnp.asarray(bl.p)
        cost_bl = float(jnp.sum(sys_.c[:, None] * jnp.asarray(bl.rho) * p_bl)
                        * sys_.T)
        assert res.cost <= cost_bl * (1 + 1e-6)


def test_matching_cost_decreases_with_swaps():
    """The returned matching is a local optimum: no single swap improves."""
    sys_, st_ = make_round(seed=13)
    res = matching.swap_matching(sys_, st_.h, st_.alpha)
    assign = res.assign.copy()
    avail = np.flatnonzero(np.asarray(st_.alpha) > 0)
    scorer = matching._Scorer(sys_, np.asarray(st_.h, np.float64),
                              np.asarray(st_.alpha, np.float64),
                              "closed_form")
    members = [np.flatnonzero(assign == n) for n in range(sys_.N)]
    base = sum(scorer.rb_cost(n, members[n]) for n in range(sys_.N))
    for u in avail:
        for k in avail:
            if k <= u or assign[u] < 0 or assign[k] < 0:
                continue
            if assign[u] == assign[k]:
                continue
            nu, nk = assign[u], assign[k]
            mu_ = np.append(members[nu][members[nu] != u], k)
            mk_ = np.append(members[nk][members[nk] != k], u)
            cand = (base
                    - scorer.rb_cost(nu, members[nu])
                    - scorer.rb_cost(nk, members[nk])
                    + scorer.rb_cost(nu, mu_)
                    + scorer.rb_cost(nk, mk_))
            assert cand >= base - 1e-12


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_noma_rate_conservation_property(seed):
    """SIC property: the sum rate of co-RB devices equals the
    single-user capacity of the total received power (information-
    theoretic identity of superposition coding)."""
    sys_, st_ = make_round(seed=seed % 2**31, K=4, N=1, Q=4)
    rho = np.ones((4, 1), np.float32)
    p = np.abs(np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed % 2**31), (4, 1)))) * 0.1
    h = np.asarray(st_.h)
    rates = np.asarray(channel.rate(sys_, jnp.asarray(rho),
                                    jnp.asarray(p), st_.h))
    total_power = float(np.sum(p[:, 0] * h[:, 0]))
    capacity = float(sys_.B) * np.log2(1 + total_power / float(sys_.N0))
    assert np.isclose(np.sum(rates), capacity, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_closed_form_power_scales_with_gamma(seed):
    """More bits per RB-second (larger L) -> strictly more power for
    every active device."""
    import dataclasses
    sys_, st_ = make_round(seed=seed % 2**31)
    res = matching.swap_matching(sys_, st_.h, st_.alpha)
    if not res.feasible:
        return
    rho = jnp.asarray(res.rho)
    p1, _ = power.closed_form_power(sys_, rho, st_.h, st_.alpha)
    sys2 = dataclasses.replace(sys_, L=sys_.L * 1.5)
    p2, _ = power.closed_form_power(sys2, rho, st_.h, st_.alpha)
    active = np.asarray(rho) * np.asarray(st_.alpha)[:, None] > 0
    assert np.all(np.asarray(p2)[active] > np.asarray(p1)[active])
