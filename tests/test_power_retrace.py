"""Retrace-freedom regression tests for the bucketed CCP inner solver.

PR 10 moved the barrier objective/gradient/Hessian from per-call
closures (which JAX retraced on every ``_inner_solve``) to module-level
functions jitted once per active-set *bucket* (``power._inner_fns``,
``power._bucket_size``).  ``power._phi_padded`` bumps a counter at
trace time, so these tests can assert the load-bearing property
directly: a second CCP solve with a *different sparsity pattern* in the
same bucket reuses the compiled Newton step — zero new traces.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import default_system, matching, power


def test_bucket_size_schedule():
    """Powers of two, floor 4 — the compilation-cache key schedule."""
    assert power._bucket_size(1) == 4
    assert power._bucket_size(4) == 4
    assert power._bucket_size(5) == 8
    assert power._bucket_size(8) == 8
    assert power._bucket_size(9) == 16
    assert power._bucket_size(250) == 256
    for m in range(1, 70):
        b = power._bucket_size(m)
        assert b >= m and b >= 4 and (b & (b - 1)) == 0


def test_inner_fns_cached_per_bucket():
    """One jit wrapper tuple per bucket, stable across calls."""
    assert power._inner_fns(8) is power._inner_fns(8)
    assert power._inner_fns(8) is not power._inner_fns(16)


def _ccp_instance(seed, K=8, N=4):
    rng = np.random.default_rng(seed)
    sys_ = default_system(K=K, N=N, Q=2)
    h = rng.gamma(2.0, 1e-5, size=(K, N))
    alpha = np.ones(K)
    res = matching.swap_matching(sys_, h, alpha)
    assert res.feasible
    return (sys_, jnp.asarray(res.rho, jnp.float32),
            jnp.asarray(h, jnp.float32), jnp.asarray(alpha, jnp.float32),
            res.assign)


@pytest.mark.slow
def test_second_ccp_solve_same_bucket_does_not_retrace():
    """The PR-10 acceptance regression: different sparsity, same bucket
    (K=8 active devices -> bucket 8) must hit the compiled cache."""
    sys_, rho1, h1, alpha, assign1 = _ccp_instance(0)
    out1 = power.ccp_power(sys_, rho1, h1, alpha)
    assert out1.feasible
    counts_after_first = power.inner_trace_counts()
    bucket_keys = [k for k in counts_after_first if k[0] == 8]
    assert bucket_keys, "warm solve should have traced the bucket-8 fns"

    # a different channel draw -> a different assignment pattern, but
    # the same K active devices, hence the same bucket
    for seed in (1, 2):
        sys2, rho2, h2, alpha2, assign2 = _ccp_instance(seed)
        out2 = power.ccp_power(sys2, rho2, h2, alpha2)
        assert out2.feasible
        if not np.array_equal(assign2, assign1):
            break
    else:  # pragma: no cover - gamma draws collide on every seed
        pytest.skip("all seeds produced the identical assignment")

    assert power.inner_trace_counts() == counts_after_first, (
        "second CCP solve retraced the inner barrier functions — the "
        "bucketed shapes or the lru-cached jit wrappers regressed")


@pytest.mark.slow
def test_padded_solve_matches_ccp_quality():
    """Bucketed padding must not change the solution: the CCP cost
    still matches the closed-form optimum after a cache-hit solve."""
    sys_, rho, h, alpha, _ = _ccp_instance(5)
    p_cf, _ = power.closed_form_power(sys_, rho, h, alpha)
    cost_cf = float(jnp.sum(sys_.c[:, None] * rho * p_cf) * sys_.T)
    out = power.ccp_power(sys_, rho, h, alpha)
    cost = float(jnp.sum(sys_.c[:, None] * rho * out.p) * sys_.T)
    assert out.feasible
    assert abs(cost - cost_cf) / cost_cf < 5e-3
