"""Unit + property tests for the Delta objective and cost model."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional test extra; property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import default_system, sample_round
from repro.core import cost as cost_mod
from repro.core import delta as delta_mod


def make_sys(K=6, N=4, Q=2, D=8):
    return default_system(K=K, N=N, Q=Q, D_hat=D)


def test_delta_simplified_equals_raw():
    sys_ = make_sys()
    st_ = sample_round(jax.random.PRNGKey(1), sys_)
    for seed in range(4):
        d = (jax.random.uniform(jax.random.PRNGKey(seed),
                                st_.sigma.shape) > 0.4).astype(jnp.float32)
        d = jnp.maximum(d, jax.nn.one_hot(0, st_.sigma.shape[1])[None, :])
        d = d * st_.sigma_mask
        a = float(delta_mod.delta(sys_, d, st_.sigma))
        b = float(delta_mod.delta_raw(sys_, d, st_.sigma))
        assert np.isclose(a, b, rtol=1e-5), (a, b)


def test_delta_literal_eq22_bruteforce():
    """Check the simplified Delta against a literal python transcription
    of eq. (22) on a tiny instance."""
    sys_ = make_sys(K=3, N=2, Q=2, D=4)
    st_ = sample_round(jax.random.PRNGKey(2), sys_)
    sigma = np.asarray(st_.sigma)
    D_hat = np.asarray(sys_.D_hat)
    eps = np.asarray(sys_.eps)
    sel = {0: [0, 2], 1: [1], 2: [0, 1, 3]}  # M_k index sets
    dlt = np.zeros_like(sigma)
    for k, idx in sel.items():
        dlt[k, idx] = 1.0

    total = 0.0
    K = sys_.K
    for k in range(K):
        own = (D_hat[k] ** 2 / (eps[k] * len(sel[k]))
               * sum(sigma[k, j] for j in sel[k]))
        cross = 0.0
        for t in range(K):
            if t == k:
                continue
            cross += (D_hat[k] * D_hat[t] / len(sel[t])
                      * sum(sigma[t, j] for j in sel[t]))
        total += own + cross
    got = float(delta_mod.delta(sys_, jnp.asarray(dlt), st_.sigma))
    assert np.isclose(got, total, rtol=1e-5)


def test_net_cost_components():
    sys_ = make_sys()
    st_ = sample_round(jax.random.PRNGKey(3), sys_)
    rho = np.zeros((sys_.K, sys_.N), np.float32)
    rho[0, 0] = 1
    rho[1, 1] = 1
    p = np.zeros_like(rho)
    p[0, 0] = 2.0
    p[1, 1] = 3.0
    c = np.asarray(sys_.c)
    T = float(sys_.T)
    expect_com = c[0] * 2.0 * T + c[1] * 3.0 * T
    got_com = float(cost_mod.cost_upload(sys_, jnp.asarray(rho),
                                         jnp.asarray(p)))
    assert np.isclose(got_com, expect_com, rtol=1e-6)

    # eq. (9)/(10)
    kappa, F, D, f = (float(sys_.kappa), np.asarray(sys_.F),
                      np.asarray(sys_.D_hat), np.asarray(sys_.f))
    expect_cmp = float(np.sum(c * kappa * F * D * f ** 2))
    got_cmp = float(cost_mod.cost_compute(sys_))
    assert np.isclose(got_cmp, expect_cmp, rtol=1e-6)

    n_sel = jnp.asarray(np.full(sys_.K, 3.0))
    expect_net = got_com + got_cmp - float(np.sum(np.asarray(sys_.q) * 3.0))
    got_net = float(cost_mod.net_cost(sys_, jnp.asarray(rho), jnp.asarray(p),
                                      n_sel))
    assert np.isclose(got_net, expect_net, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_delta_monotone_in_sigma_scale(seed):
    """Property: scaling all sigmas up scales Delta linearly."""
    sys_ = make_sys()
    st_ = sample_round(jax.random.PRNGKey(seed % 2**31), sys_)
    d = st_.sigma_mask
    base = float(delta_mod.delta(sys_, d, st_.sigma))
    scaled = float(delta_mod.delta(sys_, d, st_.sigma * 3.0))
    assert np.isclose(scaled, 3.0 * base, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_selecting_smallest_sigma_minimizes_delta(seed):
    """Property: among fixed-size selections, smallest sigmas win."""
    sys_ = make_sys(K=3, N=2, Q=2, D=5)
    st_ = sample_round(jax.random.PRNGKey(seed % 2**31), sys_)
    J = st_.sigma.shape[1]
    m = 2
    best = None
    for idx in itertools.combinations(range(J), m):
        d = np.zeros((sys_.K, J), np.float32)
        d[:, list(idx)] = 1.0
        val = float(delta_mod.delta(sys_, jnp.asarray(d), st_.sigma))
        best = val if best is None else min(best, val)
    # smallest-sigma-per-device selection
    order = np.argsort(np.asarray(st_.sigma), axis=1)
    d = np.zeros((sys_.K, J), np.float32)
    for k in range(sys_.K):
        d[k, order[k, :m]] = 1.0
    val = float(delta_mod.delta(sys_, jnp.asarray(d), st_.sigma))
    assert val <= best + 1e-4 * abs(best)
